"""HLO parsing (loop-corrected collectives), job-graph extraction, and
roofline-model tests."""

import json

import numpy as np
import pytest

from repro.core.hlo import (collect_collectives, collective_schedule,
                            parse_computations)
from repro.core.hlo_extract import step_job_graph
from repro.core.roofline import (analytic_bytes, analytic_flops,
                                 roofline_row)
from repro.configs import get_config
from repro.configs.base import (DECODE_32K, PREFILL_32K, TRAIN_4K,
                                shape_by_name)

HLO = """
HloModule jit_step

%inner_body (p: (s32[], bf16[128,256])) -> (s32[], bf16[128,256]) {
  %ag = bf16[128,256]{1,0} all-gather(%x), replica_groups=[16,16]<=[256]
  ROOT %t = (s32[], bf16[128,256]) tuple(%i, %ag)
}

%inner_cond (p: (s32[], bf16[128,256])) -> pred[] {
  ROOT %cmp = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: bf16[128,256]) -> bf16[128,256] {
  %ar = bf16[128,256]{1,0} all-reduce(%a), to_apply=%sum
  %w = (s32[], bf16[128,256]) while(%init), condition=%inner_cond, body=%inner_body, backend_config={"known_trip_count":{"n":"32"}}
  ROOT %out = bf16[128,256]{1,0} get-tuple-element(%w), index=1
}
"""


class TestHLOParser:
    def test_computations_parsed(self):
        comps = parse_computations(HLO)
        assert "main" in comps and "inner_body" in comps

    def test_loop_corrected_totals(self):
        _, totals = collect_collectives(HLO)
        block = 128 * 256 * 2  # bf16[128,256]
        assert totals["all-reduce"] == block          # once in entry
        assert totals["all-gather"] == 32 * block     # x trip count

    def test_schedule_order_and_bytes(self):
        sched = collective_schedule(HLO)
        kinds = [k for k, _ in sched]
        assert kinds == ["all-reduce", "all-gather"]
        assert all(b == 128 * 256 * 2 for _, b in sched)


class TestJobGraphExtraction:
    def test_graph_from_schedule(self):
        g = step_job_graph(HLO, n_nodes=4, total_work=100.0, skew=0.2,
                           seed=1)
        assert len(g.nodes) == 4
        g.topological_order()  # valid DAG
        # every collective became a barrier level
        assert g.stats()["depth_levels"] >= 2

    def test_schedulable(self):
        from repro.core import (compare_policies, homogeneous_cluster)

        g = step_job_graph(HLO, n_nodes=3, total_work=30.0, skew=0.3)
        specs = homogeneous_cluster(3)
        P = sum(s.lut.idle_w + 0.3 * (s.lut.p_min - s.lut.idle_w)
                for s in specs)
        res = compare_policies(g, specs, P)
        assert res["heuristic"].makespan > 0


class TestRooflineModel:
    def test_flops_scale_with_tokens(self):
        cfg = get_config("llama3-8b")
        f_train = analytic_flops(cfg, TRAIN_4K)
        f_prefill = analytic_flops(cfg, PREFILL_32K)
        # train is 3x prefill per token (fwd+bwd) + remat
        per_tok_train = f_train["model_flops"] / TRAIN_4K.tokens
        per_tok_prefill = f_prefill["model_flops"] / PREFILL_32K.tokens
        assert per_tok_train == pytest.approx(3 * per_tok_prefill)

    def test_moe_uses_active_params(self):
        cfg = get_config("arctic-480b")
        f = analytic_flops(cfg, TRAIN_4K)
        assert f["model_flops"] == pytest.approx(
            6.0 * cfg.active_param_count() * TRAIN_4K.tokens)

    def test_decode_bytes_dominated_by_kv(self):
        cfg = get_config("qwen1.5-4b")  # MHA: huge cache
        b = analytic_bytes(cfg, DECODE_32K)
        assert b["act_bytes"] > b["weight_bytes"]

    def test_roofline_row_from_artifact(self):
        rec = {
            "arch": "llama3-8b", "shape": "train_4k", "mesh": "pod16x16",
            "n_devices": 256, "peak_bytes_per_device": 8 * 2**30,
            "cost": {"flops": 1e12},
            "collectives_per_device_loop_corrected": {
                "all-reduce": 10 * 2**20, "all-gather": 5 * 2**20},
            "n_microbatches": 2,
        }
        row = roofline_row(rec)
        assert row.dominant in ("compute", "memory", "collective")
        assert 0 < row.roofline_fraction <= 1.0
        assert row.coll_bytes_per_dev == pytest.approx(
            (2 * 10 + 5) * 2**20)
