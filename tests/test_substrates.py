"""Substrate tests: optimizer (incl. int8 state), gradient compression,
data pipeline determinism, checkpoint atomicity + elastic restore."""

import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property-based tests skip without hypothesis
    from _hyp_stub import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import (DataConfig, global_batch, host_batch,
                                 skewed_host_batch)
from repro.optim import (AdamWConfig, adamw_update, clip_by_global_norm,
                         compression, global_norm, init_opt_state,
                         lr_schedule)


# ---------------------------------------------------------------- optimizer
def quad_params():
    return {"w": jnp.asarray([1.5, -2.0, 0.5]),
            "b": jnp.asarray([[0.3, -0.7], [1.1, 0.0]])}


class TestAdamW:
    @pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
    def test_converges_on_quadratic(self, state_dtype):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=200, min_lr_frac=1.0,
                          state_dtype=state_dtype)
        params = quad_params()
        state = init_opt_state(params, cfg)
        for step in range(150):
            grads = jax.tree_util.tree_map(lambda p: 2 * p, params)  # d/dp p^2
            params, state, _ = adamw_update(params, grads, state,
                                            jnp.int32(step), cfg)
        norm = float(global_norm(params))
        assert norm < 0.05, f"{state_dtype}: |params|={norm}"

    def test_int8_tracks_fp32(self):
        """int8 moments stay close to the fp32 trajectory."""
        cfg32 = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                            min_lr_frac=1.0, state_dtype="float32")
        cfg8 = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                           min_lr_frac=1.0, state_dtype="int8")
        p32 = p8 = {"w": jnp.ones((8, 256)) * 2.0}
        s32 = init_opt_state(p32, cfg32)
        s8 = init_opt_state(p8, cfg8)
        key = jax.random.PRNGKey(0)
        for step in range(30):
            key, sub = jax.random.split(key)
            g = {"w": 2 * p32["w"] +
                 0.01 * jax.random.normal(sub, (8, 256))}
            p32, s32, _ = adamw_update(p32, g, s32, jnp.int32(step), cfg32)
            g8 = {"w": 2 * p8["w"] + 0.01 * jax.random.normal(sub, (8, 256))}
            p8, s8, _ = adamw_update(p8, g8, s8, jnp.int32(step), cfg8)
        diff = float(jnp.max(jnp.abs(p32["w"] - p8["w"])))
        assert diff < 0.1, f"int8 diverged from fp32 by {diff}"

    def test_grad_clipping(self):
        grads = {"w": jnp.full((4,), 100.0)}
        clipped, norm = clip_by_global_norm(grads, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_lr_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        assert float(lr_schedule(cfg, jnp.int32(0))) == pytest.approx(0.0)
        assert float(lr_schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
        end = float(lr_schedule(cfg, jnp.int32(100)))
        assert end == pytest.approx(0.1, rel=1e-3)

    @given(st.integers(0, 5))
    @settings(max_examples=5, deadline=None)
    def test_quantize_roundtrip_bounded(self, seed):
        from repro.optim.adamw import (_dequantize_blockwise,
                                       _quantize_blockwise)

        x = jax.random.normal(jax.random.PRNGKey(seed), (7, 130)) * 3.0
        codes, scale = _quantize_blockwise(x)
        back = _dequantize_blockwise(codes, scale, x.shape)
        err = jnp.max(jnp.abs(back - x))
        assert float(err) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


class TestCompression:
    def test_error_feedback_preserves_sum(self):
        """Over many steps, compressed grads sum to the true sum (EF)."""
        g_true = jax.random.normal(jax.random.PRNGKey(1), (64,))
        err = compression.init_error_feedback({"g": g_true})
        total_hat = jnp.zeros((64,))
        for _ in range(50):
            ghat, err_g = compression.compress_decompress(g_true, err["g"])
            err = {"g": err_g}
            total_hat = total_hat + ghat
        avg = total_hat / 50
        np.testing.assert_allclose(np.asarray(avg), np.asarray(g_true),
                                   atol=0.05)


# --------------------------------------------------------------------- data
class TestDataPipeline:
    CFG = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=3)

    def test_deterministic(self):
        a = host_batch(self.CFG, step=5, host=0, n_hosts=2)
        b = host_batch(self.CFG, step=5, host=0, n_hosts=2)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_hosts_disjoint_streams(self):
        a = host_batch(self.CFG, step=5, host=0, n_hosts=2)
        b = host_batch(self.CFG, step=5, host=1, n_hosts=2)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        a = host_batch(self.CFG, step=1, host=0, n_hosts=2)
        b = host_batch(self.CFG, step=2, host=0, n_hosts=2)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_global_assembly(self):
        g = global_batch(self.CFG, step=0, n_hosts=2)
        assert g["tokens"].shape == (8, 64)
        assert g["labels"].shape == (8, 64)
        # labels are next-token of tokens where not masked
        t, l = g["tokens"], g["labels"]
        inner = (t[:, 1:] == l[:, :-1]) | (l[:, :-1] == -1)
        assert inner.mean() > 0.95

    def test_skewed_host_has_more_work(self):
        a = host_batch(self.CFG, 0, 0, 2)
        s = skewed_host_batch(self.CFG, 0, 0, 2, skew_host=0)
        pad_a = (a["tokens"] == self.CFG.pad_id).sum()
        pad_s = (s["tokens"] == self.CFG.pad_id).sum()
        assert pad_s <= pad_a

    def test_encoder_family_frames(self):
        cfg = DataConfig(vocab=32, seq_len=16, global_batch=4,
                         family="encoder", d_model=24)
        b = host_batch(cfg, 0, 0, 1)
        assert b["frames"].shape == (4, 16, 24)
        assert b["labels"].shape == (4, 16)


# --------------------------------------------------------------- checkpoint
class TestCheckpoint:
    def make_tree(self, scale=1.0):
        return {"params": {"w": jnp.full((4, 8), scale),
                           "b": jnp.arange(3.0) * scale},
                "opt": {"m": jnp.zeros((4, 8))}}

    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2)
        tree = self.make_tree(2.0)
        mgr.save(7, tree, extra={"loss": 1.25})
        restored, step, extra = mgr.restore(self.make_tree(0.0))
        assert step == 7 and extra["loss"] == 1.25
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(tree["params"]["w"]))

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self.make_tree(float(s)))
        assert mgr.completed_steps() == [3, 4]

    def test_crash_mid_write_ignored(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2)
        mgr.save(1, self.make_tree(1.0))
        # simulate a crashed writer: stray tmp dir with partial content
        crash = tmp_path / "step_000000002.tmp-deadbeef"
        crash.mkdir()
        (crash / "leaf_00000.npy").write_bytes(b"garbage")
        assert mgr.latest_step() == 1
        restored, step, _ = mgr.restore(self.make_tree(0.0))
        assert step == 1
        mgr.save(3, self.make_tree(3.0))  # gc cleans the crash dir
        assert not crash.exists()

    def test_structure_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self.make_tree())
        bad = {"params": {"w": jnp.zeros((4, 8))}}  # missing leaves
        with pytest.raises(ValueError):
            mgr.restore(bad)

    def test_elastic_restore_with_shardings(self, tmp_path):
        """Restore re-places leaves with explicit shardings (1-device
        degenerate case of elastic re-shard onto a new mesh)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        mgr = CheckpointManager(str(tmp_path))
        tree = self.make_tree(5.0)
        mgr.save(2, tree)
        mesh = jax.make_mesh((1,), ("data",))
        sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), tree)
        restored, step, _ = mgr.restore(self.make_tree(0.0), shardings=sh)
        assert step == 2
        leaf = restored["params"]["w"]
        assert leaf.sharding == NamedSharding(mesh, P())
