"""Unit tests for the compiled JAX execution backend (ISSUE 3).

Differential three-way coverage lives in ``test_batchsim_diff.py``;
this file covers the engine's own contract: validation, the jittable
policy registry, kernel-vs-reference engine parity, the heuristic's
approximate envelope, and the guarded-import surface that must stay
importable without jax installed.
"""

import pytest

from repro.backends import jax as jax_backend
from repro.core import (homogeneous_cluster, listing2_graph, simulate,
                        simulate_batch)

jax = pytest.importorskip("jax")

from repro.backends.jax import (JaxBatchSimulator,  # noqa: E402
                                simulate_batch_jax)
from repro.backends.jax.policy_fns import (get_jax_policy,  # noqa: E402
                                           has_jax_policy, jax_policies)


class TestGuardedSurface:
    def test_has_jax_reflects_environment(self):
        assert jax_backend.HAS_JAX is True
        assert jax_backend.jax_available() is True

    def test_unknown_attribute_raises(self):
        with pytest.raises(AttributeError):
            jax_backend.no_such_symbol  # noqa: B018


class TestPolicyRegistry:
    def test_all_vector_policies_have_jax_counterparts(self):
        from repro.policies import vector_policies

        assert set(vector_policies()) <= set(jax_policies())

    def test_exactness_contracts(self):
        for name in ("equal-share", "ilp", "ilp-makespan", "oracle"):
            assert get_jax_policy(name).exact, name
        heur = get_jax_policy("heuristic")
        assert not heur.exact and heur.wants_ticks

    def test_unknown_policy_raises(self):
        with pytest.raises(KeyError, match="no jax policy"):
            get_jax_policy("countdown")
        assert not has_jax_policy("countdown")


class TestValidation:
    def test_rejects_bad_dt(self):
        with pytest.raises(ValueError, match="dt"):
            simulate_batch_jax(listing2_graph(), homogeneous_cluster(3),
                               [6.0], dt=0.0)

    def test_rejects_empty_bounds(self):
        with pytest.raises(ValueError, match="bounds"):
            simulate_batch_jax(listing2_graph(), homogeneous_cluster(3),
                               [])

    def test_rejects_spec_mismatch(self):
        with pytest.raises(ValueError, match="NodeSpec"):
            simulate_batch_jax(listing2_graph(), homogeneous_cluster(2),
                               [6.0])

    def test_rejects_trace_retention(self):
        with pytest.raises(ValueError, match="trace"):
            simulate_batch_jax(listing2_graph(), homogeneous_cluster(3),
                               [6.0], trace_every=0.0)


class TestEngine:
    def test_matches_event_simulator_tightly(self):
        """Static caps + wave advancement at exact event times: float32
        noise only, far inside the differential envelope."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        for bound in (2.5, 12.0):
            ev = simulate(g, specs, bound, "equal-share")
            jx = simulate_batch_jax(g, specs, [bound], "equal-share")[0]
            assert jx.makespan == pytest.approx(ev.makespan, rel=1e-5)
            assert jx.energy_j == pytest.approx(ev.energy_j, rel=1e-5)
            assert jx.job_ends.keys() == ev.job_ends.keys()

    def test_deadlock_detection(self):
        """An acyclic DAG whose deps cross against the lanes' serial
        execution order: each lane's first job waits on the other
        lane's *second* job, so nothing ever runs."""
        from repro.core import JobDependencyGraph

        g = JobDependencyGraph()
        g.add(0, 1, 5.0, deps=[(1, 2)])
        g.add(0, 2, 5.0)
        g.add(1, 1, 5.0, deps=[(0, 2)])
        g.add(1, 2, 5.0)
        with pytest.raises(RuntimeError, match="deadlock"):
            simulate_batch_jax(g, homogeneous_cluster(2), [6.0])
        # Tick policies keep a finite next-tick forever; the stall check
        # must still fire on the completion horizon, not spin max_steps.
        with pytest.raises(RuntimeError, match="deadlock"):
            simulate_batch_jax(g, homogeneous_cluster(2), [6.0],
                               "heuristic")

    def test_heuristic_tracks_vector_heuristic(self):
        """Same tick-quantized control plane as the numpy vector
        heuristic: the two approximate backends agree closely, and both
        stay within the event heuristic's documented 10% envelope."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        for bound in (2.5, 6.0, 12.0):
            vec = simulate_batch(g, specs, [bound], "heuristic",
                                 dt=0.05)[0]
            jx = simulate_batch_jax(g, specs, [bound], "heuristic",
                                    dt=0.05)[0]
            ev = simulate(g, specs, bound, "heuristic")
            assert jx.makespan == pytest.approx(vec.makespan, rel=0.02)
            assert jx.makespan == pytest.approx(ev.makespan, rel=0.10)

    def test_heuristic_surges_above_bound(self):
        """The delayed cap application reproduces the vector
        heuristic's transient over-budget surges at tight bounds —
        same control plane, same surge accounting."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        bound = 1.8
        vec = simulate_batch(g, specs, [bound], "heuristic", dt=0.05)[0]
        jx = simulate_batch_jax(g, specs, [bound], "heuristic",
                                dt=0.05)[0]
        assert jx.peak_power_w > bound
        assert jx.over_budget_time > 0
        assert jx.over_budget_time == pytest.approx(
            vec.over_budget_time, rel=0.05)

    def test_policy_instance_and_kwargs_routes(self):
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        policy = get_jax_policy("equal-share")
        r = JaxBatchSimulator(g, specs, [6.0], policy=policy).run()[0]
        ref = simulate(g, specs, 6.0, "equal-share")
        assert r.makespan == pytest.approx(ref.makespan, rel=1e-5)
        with pytest.raises(ValueError, match="policy_kwargs"):
            JaxBatchSimulator(g, specs, [6.0], policy=policy,
                              time_limit=5.0)


class TestDispatchPipeline:
    def test_single_transfer_per_run(self, monkeypatch):
        """The whole output pytree comes back in ONE device-to-host
        fetch — eager per-field unpacking would sync once per array."""
        from repro.backends.jax import engine

        calls = []
        real = engine._device_get

        def counting(tree):
            calls.append(tree)
            return real(tree)

        monkeypatch.setattr(engine, "_device_get", counting)
        results = JaxBatchSimulator(listing2_graph(),
                                    homogeneous_cluster(3),
                                    [2.5, 6.0, 12.0]).run()
        assert len(results) == 3
        assert len(calls) == 1
        # ...and it really was the whole pytree, not a single leaf
        assert isinstance(calls[0], dict) and len(calls[0]) > 3

    def test_dispatch_fetch_round_trip(self):
        """run() == fetch(dispatch()) with a populated profile."""
        sim = JaxBatchSimulator(listing2_graph(), homogeneous_cluster(3),
                                [6.0, 9.0])
        pending = sim.dispatch()
        assert pending.profile.rows == 2
        assert pending.profile.cache_key is not None
        results = sim.fetch(pending)
        ref = simulate(listing2_graph(), homogeneous_cluster(3), 6.0,
                       "equal-share")
        assert results[0].makespan == pytest.approx(ref.makespan,
                                                    rel=1e-5)
        assert pending.profile.transfer_s >= 0.0

    @pytest.mark.parametrize("pipeline", [True, False])
    def test_failed_fetch_keeps_profile(self, monkeypatch, pipeline):
        """Profiles are recorded at *dispatch*: a bucket whose fetch
        explodes must still appear in ``SweepResult.profile`` — under
        both the pipelined and the sequential dispatch paths (the
        sequential path used to drop it)."""
        from repro.core import SweepEngine, scenario_grid

        def exploding_fetch(self, pending):
            raise RuntimeError("transfer lost")

        monkeypatch.setattr(JaxBatchSimulator, "fetch", exploding_fetch)
        grid = scenario_grid({"l2": listing2_graph()},
                             homogeneous_cluster(3), [6.0, 9.0],
                             ["equal-share"])
        result = SweepEngine(executor="jax", pipeline=pipeline).run(grid)
        assert len(result.failures) == len(grid)
        assert all("transfer lost" in r.error for r in result.failures)
        assert result.profile is not None
        assert len(result.profile.buckets) == 1
        assert result.profile.buckets[0].bucket \
            == result.failures[0].bucket

    def test_compile_attribution_is_per_cache_key(self, monkeypatch):
        """Interleaved dispatches of a warm envelope and a fresh one:
        ``compiled`` lands on the fresh bucket only.  The old global
        cache-size delta charged whichever dispatch raced the check."""
        from repro.backends.jax import engine

        monkeypatch.setattr(engine, "_compiled_keys", set())
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        warm = JaxBatchSimulator(g, specs, [6.0, 9.0])
        p1 = warm.dispatch()            # claims the envelope's key
        again = JaxBatchSimulator(g, specs, [2.5, 12.0])
        p2 = again.dispatch()           # same key -> cached
        fresh = JaxBatchSimulator(g, specs, [6.0, 9.0],
                                  policy="oracle")
        p3 = fresh.dispatch()           # new policy -> new key
        assert p1.profile.compiled is True
        assert p2.profile.compiled is False
        assert p3.profile.compiled is True
        assert p2.profile.cache_key == p1.profile.cache_key
        assert p3.profile.cache_key != p1.profile.cache_key
        assert p2.profile.compile_s == 0.0
        for sim, pending in ((warm, p1), (again, p2), (fresh, p3)):
            assert len(sim.fetch(pending)) == 2

    def test_claim_cache_key_single_winner_under_threads(self):
        """Concurrent dispatches of one envelope must attribute the
        compile to exactly one of them."""
        import threading

        from repro.backends.jax.engine import _claim_cache_key

        key = ("claim-race-test", 0)
        wins = []
        barrier = threading.Barrier(8)

        def claim():
            barrier.wait()
            if _claim_cache_key(key):
                wins.append(1)

        threads = [threading.Thread(target=claim) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(wins) == 1
        assert _claim_cache_key(key) is False

    def test_rerun_is_compile_free(self):
        """Re-running the same mixed family through the sweep engine
        must hit the jit cache on every bucket: the cache key (padding
        envelope + shard spec + policy name) is stable across runs."""
        from repro.core import (SweepEngine, listing2_uniform,
                                scenario_grid)

        grid = scenario_grid(
            {"l2": listing2_graph(), "u": listing2_uniform(10.0)},
            homogeneous_cluster(3), [6.0, 9.0],
            ["equal-share", "oracle"])
        engine = SweepEngine(executor="jax")
        first = engine.run(grid)
        assert not first.failures and first.profile is not None
        again = SweepEngine(executor="jax").run(grid)
        assert not again.failures
        assert again.profile.compiles == 0
        assert again.profile.cache_hits == len(again.profile.buckets)
        assert "jit:" in again.backend_summary()


class TestInterpretDefault:
    def test_cpu_defaults_to_interpreter(self):
        """power_step resolves interpret=None from the backend: the
        Pallas interpreter on CPU, native lowering elsewhere."""
        from repro.kernels.power_step import default_interpret

        expected = jax.default_backend() == "cpu"
        assert default_interpret() is expected

    def test_engine_inherits_backend_default(self):
        sim = JaxBatchSimulator(listing2_graph(), homogeneous_cluster(3),
                                [6.0], use_kernel=True)
        from repro.kernels.power_step import default_interpret

        assert sim.kernel_interpret == default_interpret()


class TestKernelEngineParity:
    def test_use_kernel_matches_ref_engine(self):
        """The Pallas-kernel engine (interpret mode) and the jnp
        reference engine walk identical wave sequences."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        bounds = [2.5, 6.0, 12.0]
        for policy in ("equal-share", "oracle"):
            ref = simulate_batch_jax(g, specs, bounds, policy)
            ker = simulate_batch_jax(g, specs, bounds, policy,
                                     use_kernel=True,
                                     kernel_interpret=True)
            for a, b in zip(ref, ker):
                assert b.makespan == pytest.approx(a.makespan, rel=1e-6)
                assert b.energy_j == pytest.approx(a.energy_j, rel=1e-6)
