"""Pallas kernel tests: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,Hkv,S,dh", [
        (1, 4, 4, 128, 64),     # MHA
        (2, 8, 2, 256, 64),     # GQA 4:1
        (1, 4, 1, 128, 128),    # MQA, MXU-width head
        (1, 2, 2, 512, 32),     # long-ish seq
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, B, H, Hkv, S, dh, dtype, causal):
        k1, k2, k3 = jax.random.split(KEY, 3)
        q = rand(k1, (B, S, H, dh), dtype)
        k = rand(k2, (B, S, Hkv, dh), dtype)
        v = rand(k3, (B, S, Hkv, dh), dtype)
        got = ops.flash_attention(q, k, v, causal=causal, block_q=64,
                                  block_kv=64, interpret=True)
        want = ref.flash_attention_ref(
            jnp.swapaxes(jnp.swapaxes(q, 1, 2), 1, 2), k, v, causal=causal)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    def test_block_size_invariance(self):
        q = rand(KEY, (1, 256, 4, 64), jnp.float32)
        k = rand(KEY, (1, 256, 4, 64), jnp.float32)
        v = rand(KEY, (1, 256, 4, 64), jnp.float32)
        a = ops.flash_attention(q, k, v, block_q=64, block_kv=64,
                                interpret=True)
        b = ops.flash_attention(q, k, v, block_q=128, block_kv=32,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    def test_rejects_ragged(self):
        q = rand(KEY, (1, 100, 4, 64), jnp.float32)
        with pytest.raises(ValueError):
            ops.flash_attention(q, q, q, block_q=64, block_kv=64,
                                interpret=True)


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(8, 128), (2, 16, 256), (1, 512),
                                       (3, 5, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype):
        x = rand(KEY, shape, dtype)
        gamma = rand(jax.random.PRNGKey(1), (shape[-1],), dtype) + 1.0
        got = ops.rmsnorm(x, gamma, interpret=True)
        want = ref.rmsnorm_ref(x, gamma)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    def test_matches_model_layer(self):
        from repro.models.layers import rmsnorm as model_rmsnorm

        x = rand(KEY, (4, 96), jnp.float32)
        g = rand(jax.random.PRNGKey(2), (96,), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(ops.rmsnorm(x, g, interpret=True)),
            np.asarray(model_rmsnorm(x, g)), rtol=1e-5, atol=1e-5)


class TestSSMScan:
    @pytest.mark.parametrize("B,H,S,P,N,chunk", [
        (1, 2, 64, 8, 16, 16),
        (2, 3, 128, 16, 8, 64),
        (1, 1, 256, 32, 32, 256),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, H, S, P, N, chunk, dtype):
        ks = jax.random.split(KEY, 5)
        x = rand(ks[0], (B, H, S, P), dtype)
        a = -jnp.abs(rand(ks[1], (B, H, S), jnp.float32)) * 0.2
        dt = jnp.abs(rand(ks[2], (B, H, S), jnp.float32))
        Bm = rand(ks[3], (B, S, N), dtype)
        Cm = rand(ks[4], (B, S, N), dtype)
        got = ops.ssm_scan(x, a, dt, Bm, Cm, chunk=chunk, interpret=True)
        want = ref.ssm_scan_ref(
            jnp.moveaxis(x, 1, 2).astype(jnp.float32),
            jnp.moveaxis(a, 1, 2), jnp.moveaxis(dt, 1, 2), Bm, Cm)
        want = jnp.moveaxis(want, 1, 2)  # back to (B,H,S,P)
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    def test_chunk_invariance(self):
        ks = jax.random.split(KEY, 5)
        B, H, S, P, N = 1, 2, 128, 8, 8
        x = rand(ks[0], (B, H, S, P), jnp.float32)
        a = -jnp.abs(rand(ks[1], (B, H, S), jnp.float32)) * 0.2
        dt = jnp.abs(rand(ks[2], (B, H, S), jnp.float32))
        Bm = rand(ks[3], (B, S, N), jnp.float32)
        Cm = rand(ks[4], (B, S, N), jnp.float32)
        y1 = ops.ssm_scan(x, a, dt, Bm, Cm, chunk=32, interpret=True)
        y2 = ops.ssm_scan(x, a, dt, Bm, Cm, chunk=128, interpret=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)
