"""Pallas kernel tests: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,Hkv,S,dh", [
        (1, 4, 4, 128, 64),     # MHA
        (2, 8, 2, 256, 64),     # GQA 4:1
        (1, 4, 1, 128, 128),    # MQA, MXU-width head
        (1, 2, 2, 512, 32),     # long-ish seq
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, B, H, Hkv, S, dh, dtype, causal):
        k1, k2, k3 = jax.random.split(KEY, 3)
        q = rand(k1, (B, S, H, dh), dtype)
        k = rand(k2, (B, S, Hkv, dh), dtype)
        v = rand(k3, (B, S, Hkv, dh), dtype)
        got = ops.flash_attention(q, k, v, causal=causal, block_q=64,
                                  block_kv=64, interpret=True)
        want = ref.flash_attention_ref(
            jnp.swapaxes(jnp.swapaxes(q, 1, 2), 1, 2), k, v, causal=causal)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    def test_block_size_invariance(self):
        q = rand(KEY, (1, 256, 4, 64), jnp.float32)
        k = rand(KEY, (1, 256, 4, 64), jnp.float32)
        v = rand(KEY, (1, 256, 4, 64), jnp.float32)
        a = ops.flash_attention(q, k, v, block_q=64, block_kv=64,
                                interpret=True)
        b = ops.flash_attention(q, k, v, block_q=128, block_kv=32,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    def test_rejects_ragged(self):
        q = rand(KEY, (1, 100, 4, 64), jnp.float32)
        with pytest.raises(ValueError):
            ops.flash_attention(q, q, q, block_q=64, block_kv=64,
                                interpret=True)


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(8, 128), (2, 16, 256), (1, 512),
                                       (3, 5, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype):
        x = rand(KEY, shape, dtype)
        gamma = rand(jax.random.PRNGKey(1), (shape[-1],), dtype) + 1.0
        got = ops.rmsnorm(x, gamma, interpret=True)
        want = ref.rmsnorm_ref(x, gamma)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    def test_matches_model_layer(self):
        from repro.models.layers import rmsnorm as model_rmsnorm

        x = rand(KEY, (4, 96), jnp.float32)
        g = rand(jax.random.PRNGKey(2), (96,), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(ops.rmsnorm(x, g, interpret=True)),
            np.asarray(model_rmsnorm(x, g)), rtol=1e-5, atol=1e-5)


class TestSSMScan:
    @pytest.mark.parametrize("B,H,S,P,N,chunk", [
        (1, 2, 64, 8, 16, 16),
        (2, 3, 128, 16, 8, 64),
        (1, 1, 256, 32, 32, 256),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, H, S, P, N, chunk, dtype):
        ks = jax.random.split(KEY, 5)
        x = rand(ks[0], (B, H, S, P), dtype)
        a = -jnp.abs(rand(ks[1], (B, H, S), jnp.float32)) * 0.2
        dt = jnp.abs(rand(ks[2], (B, H, S), jnp.float32))
        Bm = rand(ks[3], (B, S, N), dtype)
        Cm = rand(ks[4], (B, S, N), dtype)
        got = ops.ssm_scan(x, a, dt, Bm, Cm, chunk=chunk, interpret=True)
        want = ref.ssm_scan_ref(
            jnp.moveaxis(x, 1, 2).astype(jnp.float32),
            jnp.moveaxis(a, 1, 2), jnp.moveaxis(dt, 1, 2), Bm, Cm)
        want = jnp.moveaxis(want, 1, 2)  # back to (B,H,S,P)
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=tol, atol=tol)

    def test_chunk_invariance(self):
        ks = jax.random.split(KEY, 5)
        B, H, S, P, N = 1, 2, 128, 8, 8
        x = rand(ks[0], (B, H, S, P), jnp.float32)
        a = -jnp.abs(rand(ks[1], (B, H, S), jnp.float32)) * 0.2
        dt = jnp.abs(rand(ks[2], (B, H, S), jnp.float32))
        Bm = rand(ks[3], (B, S, N), jnp.float32)
        Cm = rand(ks[4], (B, S, N), jnp.float32)
        y1 = ops.ssm_scan(x, a, dt, Bm, Cm, chunk=32, interpret=True)
        y2 = ops.ssm_scan(x, a, dt, Bm, Cm, chunk=128, interpret=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)


class TestPowerStep:
    """Fused power-redistribution step: Pallas (interpret) vs jnp
    reference, and both vs the numpy translation/waterfill oracles."""

    def _tables(self, n=5, seed=0):
        from repro.core.power import heterogeneous_cluster, lut_table
        from repro.kernels.power_step import step_tables

        specs = heterogeneous_cluster(n, seed=seed)  # ragged LUT pads
        table = lut_table(specs)
        return specs, table, step_tables(table)

    def _inputs(self, table, seed=1):
        n = table.n_nodes
        rng = np.random.default_rng(seed)
        caps = rng.uniform(0.2, 1.2 * float(table.p_max.max()), (1, n))
        running = (rng.random((1, n)) < 0.7).astype(np.float32)
        remaining = rng.uniform(0.0, 50.0, (1, n))
        rho = rng.uniform(0.1, 1.0, (1, n))
        bound = np.array([[rng.uniform(float(table.idle_w.sum()),
                                       float(table.p_max.sum()))]])
        f32 = lambda a: jnp.asarray(a, jnp.float32)  # noqa: E731
        return tuple(map(f32, (caps, running, remaining, rho, bound)))

    @pytest.mark.parametrize("redistribute", [False, True])
    @pytest.mark.parametrize("seed", [1, 2, 3])
    def test_pallas_matches_ref(self, redistribute, seed):
        from repro.kernels.power_step import (power_step_pallas,
                                              power_step_ref)

        _, table, tab = self._tables()
        args = self._inputs(table, seed=seed)
        got = power_step_pallas(tab, *args, redistribute=redistribute,
                                interpret=True)
        want = power_step_ref(tab, *args, redistribute=redistribute)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-6, atol=1e-6)

    def test_pallas_matches_ref_under_vmap(self):
        """The engine vmaps the kernel over the bound axis; Pallas'
        batching rule must agree with vmapping the reference."""
        from repro.kernels.power_step import (power_step_pallas,
                                              power_step_ref)

        _, table, tab = self._tables()
        rows = [self._inputs(table, seed=s) for s in (4, 5, 6)]
        batched = tuple(jnp.stack(a) for a in zip(*rows))
        got = jax.vmap(lambda c, r, m, h, b: power_step_pallas(
            tab, c, r, m, h, b, redistribute=True, interpret=True))(*batched)
        want = jax.vmap(lambda c, r, m, h, b: power_step_ref(
            tab, c, r, m, h, b, redistribute=True))(*batched)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-6, atol=1e-6)

    def test_translate_matches_numpy_oracle(self):
        """The in-kernel gather reproduces batched_operating_point /
        batched_rates (the numpy backend's translator) on a mixed grid
        of caps, including sub-p_min duty states and ragged LUT pads."""
        from repro.core.power import (batched_operating_point,
                                      batched_rates)
        from repro.kernels.power_step import power_step_ref

        _, table, tab = self._tables()
        n = table.n_nodes
        rng = np.random.default_rng(7)
        caps = rng.uniform(0.2, 1.2 * float(table.p_max.max()), (16, n))
        freq, duty, power = batched_operating_point(table, caps)
        rho = rng.uniform(0.1, 1.0, (16, n))
        rate_np = batched_rates(table, freq, duty, rho)
        remaining = rng.uniform(0.1, 50.0, (16, n))
        for i in range(16):
            f32 = lambda a: jnp.asarray(a[i:i + 1], jnp.float32)  # noqa: E731
            rate, p_node, t_fin, eff, p_cl, t_comp = power_step_ref(
                tab, f32(caps), jnp.ones((1, n), jnp.float32),
                f32(remaining), f32(rho), jnp.ones((1, 1), jnp.float32))
            np.testing.assert_allclose(np.asarray(rate)[0], rate_np[i],
                                       rtol=1e-5)
            np.testing.assert_allclose(np.asarray(p_node)[0], power[i],
                                       rtol=1e-5)
            np.testing.assert_allclose(np.asarray(t_comp)[0, 0],
                                       (remaining[i] / rate_np[i]).min(),
                                       rtol=1e-4)

    def test_waterfill_matches_numpy_oracle(self):
        """waterfill_caps agrees with the vector backend's
        batched_waterfill row for row."""
        from repro.kernels.power_step import waterfill_caps
        from repro.policies.vector import batched_waterfill

        _, table, tab = self._tables()
        n = table.n_nodes
        rng = np.random.default_rng(9)
        running = rng.random((32, n)) < 0.6
        budget = rng.uniform(0.0, float(table.p_max.sum()), 32)
        want = batched_waterfill(running, budget, table)
        for i in range(32):
            got = waterfill_caps(
                tab, jnp.asarray(running[i:i + 1]),
                jnp.asarray(budget[i:i + 1, None], jnp.float32))
            np.testing.assert_allclose(np.asarray(got)[0], want[i],
                                       rtol=1e-5, atol=1e-5)
