"""BENCH regression gate (ISSUE 10): classifier, differ, CLI exits.

The acceptance contract, tested in both directions: ``python -m
repro.obs regress`` exits 0 on identical artifacts and nonzero when a
makespan (quality) or throughput (higher-is-better) metric is pushed
past its hard threshold; metadata skew refuses (exit 2) instead of
producing an apples-to-oranges diff.
"""

import json
import pathlib

import pytest

from repro.obs.regress import (RefusalError, classify,
                               compare_payloads, main,
                               markdown_report, split_payload)


def payload(benches, meta=None):
    return {"meta": meta if meta is not None
            else {"schema_version": 1, "backend": "cpu",
                  "device_kind": "cpu"},
            "benches": benches}


BASE = {"fig8": {"makespan": 10.0, "wall_s": 1.0,
                 "throughput_rps": 100.0, "recompiles": 0,
                 "cells": 500}}


def write_dir(tmp_path, name, benches, meta=None,
              fname="BENCH_sweep.json"):
    d = tmp_path / name
    d.mkdir(exist_ok=True)
    (d / fname).write_text(json.dumps(payload(benches, meta)))
    return str(d)


# ---------------------------------------------------------- classifier
class TestClassify:
    @pytest.mark.parametrize("metric,klass", [
        ("a.fallbacks", "structural"),
        ("serve.recompiles", "structural"),
        ("x.failures", "structural"),
        ("fig8.makespan", "quality"),
        ("fig8.max_makespan_diff_vs_offline", "quality"),
        ("x.rel_err", "quality"),
        ("serve.throughput_rps", "higher"),
        ("sharded.speedup", "higher"),
        ("fig8.wall_s", "lower"),
        ("fig8.us_per_cell", "lower"),
        ("serve.latency_p99_s", "lower"),
        ("grid.cells", None),
    ])
    def test_by_name(self, metric, klass):
        assert classify(metric) == klass

    def test_last_component_wins(self):
        # the bench name must not leak into classification
        assert classify("serve_stream.grid.cells") is None


# -------------------------------------------------------------- differ
class TestComparePayloads:
    def test_identical_is_clean(self):
        findings = compare_payloads(payload(BASE), payload(BASE))
        assert all(f.status in ("ok", "info") for f in findings)

    def test_quality_hard_regression(self):
        cur = {"fig8": dict(BASE["fig8"], makespan=11.0)}
        findings = compare_payloads(payload(BASE), payload(cur))
        bad = [f for f in findings if f.metric == "fig8.makespan"]
        assert bad[0].status == "hard"
        assert bad[0].delta_pct == pytest.approx(10.0)

    def test_quality_soft_band(self):
        cur = {"fig8": dict(BASE["fig8"], makespan=10.3)}
        findings = compare_payloads(payload(BASE), payload(cur))
        assert [f for f in findings
                if f.metric == "fig8.makespan"][0].status == "soft"

    def test_quality_improvement_is_ok(self):
        cur = {"fig8": dict(BASE["fig8"], makespan=9.0)}
        findings = compare_payloads(payload(BASE), payload(cur))
        assert [f for f in findings
                if f.metric == "fig8.makespan"][0].status == "ok"

    def test_throughput_drop_is_hard(self):
        cur = {"fig8": dict(BASE["fig8"], throughput_rps=40.0)}
        findings = compare_payloads(payload(BASE), payload(cur))
        f = [x for x in findings
             if x.metric == "fig8.throughput_rps"][0]
        assert f.status == "hard"

    def test_throughput_gain_is_ok(self):
        cur = {"fig8": dict(BASE["fig8"], throughput_rps=300.0)}
        findings = compare_payloads(payload(BASE), payload(cur))
        assert [x for x in findings
                if x.metric == "fig8.throughput_rps"][0].status == "ok"

    def test_structural_any_increase_is_hard(self):
        cur = {"fig8": dict(BASE["fig8"], recompiles=1)}
        findings = compare_payloads(payload(BASE), payload(cur))
        assert [f for f in findings
                if f.metric == "fig8.recompiles"][0].status == "hard"

    def test_timing_soft_downgrades_only_timing(self):
        cur = {"fig8": dict(BASE["fig8"], wall_s=3.0, makespan=11.0)}
        findings = compare_payloads(payload(BASE), payload(cur),
                                    timing_soft=True)
        by = {f.metric: f.status for f in findings}
        assert by["fig8.wall_s"] == "soft"       # downgraded
        assert by["fig8.makespan"] == "hard"     # quality still gates

    def test_missing_and_new_metrics(self):
        cur = {"fig8": {"makespan": 10.0, "extra": 1.0}}
        statuses = {f.metric: f.status for f in compare_payloads(
            payload(BASE), payload(cur))}
        assert statuses["fig8.wall_s"] == "missing"
        assert statuses["fig8.extra"] == "new"

    def test_schema_mismatch_refuses(self):
        with pytest.raises(RefusalError):
            compare_payloads(payload(BASE),
                             payload(BASE, {"schema_version": 2}))

    def test_backend_mismatch_refuses(self):
        with pytest.raises(RefusalError):
            compare_payloads(
                payload(BASE, {"backend": "cpu"}),
                payload(BASE, {"backend": "gpu"}))

    def test_legacy_unwrapped_payload(self):
        meta, benches = split_payload(BASE)
        assert meta == {} and benches is BASE
        findings = compare_payloads(BASE, payload(BASE))
        assert all(f.status in ("ok", "info") for f in findings)


# -------------------------------------------------------------- report
class TestReport:
    def test_markdown_contains_verdicts(self):
        cur = {"fig8": dict(BASE["fig8"], makespan=11.0)}
        findings = compare_payloads(payload(BASE), payload(cur))
        report = markdown_report(findings, ["note-1"])
        assert "**1 hard**" in report
        assert "`fig8.makespan`" in report
        assert "| hard" in report
        assert "note-1" in report


# ----------------------------------------------------------------- CLI
class TestCli:
    def test_identical_dirs_exit_zero(self, tmp_path, capsys):
        base = write_dir(tmp_path, "base", BASE)
        cur = write_dir(tmp_path, "cur", BASE)
        assert main(["regress", "--baseline", base,
                     "--current", cur]) == 0
        assert "0 hard" in capsys.readouterr().out

    def test_injected_makespan_regression_exits_nonzero(
            self, tmp_path, capsys):
        base = write_dir(tmp_path, "base", BASE)
        cur = write_dir(tmp_path, "cur",
                        {"fig8": dict(BASE["fig8"], makespan=11.0)})
        assert main(["regress", "--baseline", base,
                     "--current", cur]) == 1
        assert "fig8.makespan" in capsys.readouterr().out

    def test_injected_throughput_regression_exits_nonzero(
            self, tmp_path):
        base = write_dir(tmp_path, "base", BASE)
        cur = write_dir(
            tmp_path, "cur",
            {"fig8": dict(BASE["fig8"], throughput_rps=40.0)})
        assert main(["regress", "--baseline", base,
                     "--current", cur]) == 1

    def test_meta_mismatch_exits_two(self, tmp_path, capsys):
        base = write_dir(tmp_path, "base", BASE)
        cur = write_dir(tmp_path, "cur", BASE,
                        meta={"schema_version": 2})
        assert main(["regress", "--baseline", base,
                     "--current", cur]) == 2
        assert "REFUSED" in capsys.readouterr().out

    def test_missing_artifact_is_hard(self, tmp_path):
        base = write_dir(tmp_path, "base", BASE)
        cur = tmp_path / "cur"
        cur.mkdir()
        assert main(["regress", "--baseline", base,
                     "--current", str(cur)]) == 1

    def test_no_baselines_refuses(self, tmp_path):
        base = tmp_path / "base"
        base.mkdir()
        cur = write_dir(tmp_path, "cur", BASE)
        assert main(["regress", "--baseline", str(base),
                     "--current", cur]) == 2

    def test_report_file_written(self, tmp_path):
        base = write_dir(tmp_path, "base", BASE)
        cur = write_dir(tmp_path, "cur", BASE)
        report = tmp_path / "report.md"
        assert main(["regress", "--baseline", base, "--current", cur,
                     "--report", str(report)]) == 0
        assert "Bench regression report" in report.read_text()

    def test_new_artifact_is_note_not_failure(self, tmp_path, capsys):
        base = write_dir(tmp_path, "base", BASE)
        cur = write_dir(tmp_path, "cur", BASE)
        write_dir(tmp_path, "cur", BASE, fname="BENCH_serve.json")
        assert main(["regress", "--baseline", base,
                     "--current", cur]) == 0
        assert "no baseline yet" in capsys.readouterr().out

    def test_committed_baselines_self_compare(self, capsys):
        # the artifacts seeded for CI must pass their own gate
        baselines = str(pathlib.Path(__file__).resolve().parents[1]
                        / "benchmarks" / "baselines")
        assert main(["regress", "--baseline", baselines,
                     "--current", baselines]) == 0
