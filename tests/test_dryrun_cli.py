"""End-to-end dry-run CLI test: compiles one real cell against the
production mesh in a subprocess (the XLA_FLAGS device-count override
requires a fresh interpreter) and checks the artifact schema."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.parametrize("arch,shape", [("xlstm-350m", "decode_32k")])
def test_dryrun_cell_subprocess(tmp_path, arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    artifact = tmp_path / f"{arch}__{shape}__pod16x16.json"
    assert artifact.exists()
    rec = json.loads(artifact.read_text())
    assert rec["n_devices"] == 256
    assert rec["peak_bytes_per_device"] > 0
    assert rec["cost"].get("flops", 0) > 0
    assert "collectives_per_device_loop_corrected" in rec


def test_skip_cell_reports_reason(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "hubert-xlarge", "--shape", "decode_32k", "--out",
         str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0
    assert "skip: encoder-only" in proc.stdout
