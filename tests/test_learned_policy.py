"""Contract tests for the ``"learned"`` cap policy (ISSUE 9).

The gradient-trained MLP policy must be a *first-class citizen*: present
in all three policy registries (event / vector / jax), constructible
kwarglessly from the bundled checkpoint, honest about its exactness
contract, and safe under the SweepService's phantom-row padding with
zero steady-state recompiles.  The event and vector adapters run on
numpy alone, so most of this file executes in the jax-free tier-1
environment; the compiled-backend classes are guarded.

End-to-end: the bundled checkpoint (trained through
``repro.diff.softsim`` on seeds 1-3/9) must beat equal-share *on
average* over a held-out scenario family (seed 77 — disjoint from
training) and stay within a few percent of the hand-tuned heuristic.
The mean-ratio form is deliberate: a learned policy may lose individual
loose-bound scenarios while clearly winning the family.
"""

import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import (homogeneous_cluster, listing2_graph, scenario_grid,
                        simulate, simulate_batch)
from repro.core.scenarios import random_layered_family
from repro.core.workloads import layered_dag
from repro.backends import jax as jax_backend
from repro.policies import (available_policies, get_policy,
                            get_vector_policy, vector_policies)
from repro.policies import learned as learned_mod

needs_jax = pytest.mark.skipif(not jax_backend.HAS_JAX,
                               reason="jax not installed")

REPO = Path(__file__).resolve().parent.parent
BUNDLED = REPO / "src" / "repro" / "policies" / "learned_default.json"
EXAMPLE = REPO / "examples" / "learned" / "mlp_seed0.json"


# ---------------------------------------------------------------- registry
class TestRegistry:
    def test_first_class_in_every_registry(self):
        assert "learned" in available_policies()
        assert "learned" in vector_policies()
        if jax_backend.HAS_JAX:
            from repro.backends.jax.policy_fns import jax_policies

            assert "learned" in jax_policies()

    def test_kwargless_construction_loads_default_checkpoint(self):
        ev = get_policy("learned")
        vec = get_vector_policy("learned")
        assert ev.name == "learned" and vec.name == "learned"

    def test_exactness_flags_are_honest(self):
        """The jax adapter runs in float32, so the differential contract
        is the loose envelope — neither batch adapter claims ``exact``."""
        assert get_vector_policy("learned").exact is False
        if jax_backend.HAS_JAX:
            from repro.backends.jax.policy_fns import get_jax_policy

            assert get_jax_policy("learned").exact is False

    def test_checkpoint_shapes_match_declared_arch(self):
        params = learned_mod.load_checkpoint()
        f, (h1, h2) = learned_mod.FEATURE_DIM, learned_mod.HIDDEN
        assert params["W1"].shape == (f, h1)
        assert params["b1"].shape == (h1,)
        assert params["W2"].shape == (h1, h2)
        assert params["b2"].shape == (h2,)
        assert params["w3"].shape == (h2,)

    def test_explicit_checkpoint_path_accepted(self):
        params = learned_mod.load_checkpoint(EXAMPLE)
        for k, v in learned_mod.load_checkpoint(BUNDLED).items():
            assert np.array_equal(params[k], v)


class TestCheckpointSync:
    def test_example_checkpoint_is_the_bundled_one(self):
        """examples/learned/mlp_seed0.json documents how the bundled
        default was produced; the two must never drift apart."""
        a = json.loads(BUNDLED.read_text())
        b = json.loads(EXAMPLE.read_text())
        assert a["arch"] == b["arch"]
        assert a["params"] == b["params"]


# ------------------------------------------------- event/vector agreement
class TestEventVectorAgreement:
    """Both numpy adapters share ``compute_caps`` and resolve transitions
    at exact event times, so they agree to float noise — no jax needed."""

    @pytest.mark.parametrize("bound", [4.0, 6.0, 9.0])
    def test_listing2(self, bound):
        g, specs = listing2_graph(), homogeneous_cluster(3)
        ev = simulate(g, specs, bound, "learned")
        vec = simulate_batch(g, specs, [bound], "learned")[0]
        assert vec.makespan == pytest.approx(ev.makespan, rel=1e-9)
        assert vec.energy_j == pytest.approx(ev.energy_j, rel=1e-6)

    def test_rho_diverse_graph(self):
        g = layered_dag(5, layers=3, fan=2, seed=21)
        specs = homogeneous_cluster(5)
        for bound in (8.0, 14.0):
            ev = simulate(g, specs, bound, "learned")
            vec = simulate_batch(g, specs, [bound], "learned")[0]
            assert vec.makespan == pytest.approx(ev.makespan, rel=1e-9)


# ------------------------------------------------------- compiled backend
@needs_jax
class TestJaxBackend:
    @pytest.mark.parametrize("case", ["l2", "layered"])
    def test_matches_vector_backend(self, case):
        from repro.backends.jax import simulate_batch_jax

        if case == "l2":
            g, specs = listing2_graph(), homogeneous_cluster(3)
            bounds = [4.0, 6.0, 9.0]
        else:   # rho-diverse: exercises the chained-job refill path
            g = layered_dag(5, layers=3, fan=2, seed=21)
            specs = homogeneous_cluster(5)
            bounds = [8.0, 14.0]
        vec = simulate_batch(g, specs, bounds, "learned")
        jx = simulate_batch_jax(g, specs, bounds, "learned")
        for v, j in zip(vec, jx):
            assert j.makespan == pytest.approx(v.makespan, rel=1e-3)

    def test_compile_once_across_service_buckets(self, monkeypatch):
        """A long-lived service never recompiles the learned policy in
        steady state: fresh bounds in wave 2 reuse wave 1's signature
        (temperature-free — the MLP is baked into the trace), and no
        request falls back to the event leg."""
        from repro.backends.jax import engine
        from repro.serving import SweepService

        monkeypatch.setattr(engine, "_compiled_keys", set())
        cells1 = scenario_grid({"l2": listing2_graph()},
                               homogeneous_cluster(3), [6.0, 9.0],
                               ["learned"])
        cells2 = scenario_grid({"l2": listing2_graph()},
                               homogeneous_cluster(3), [5.0, 8.0, 11.0],
                               ["learned"])
        with SweepService(executor="jax", flush_deadline_s=0.02,
                          bucket_rows=4) as service:
            wave1 = [t.result(120) for t in service.submit_many(cells1)]
            service.drain(timeout=60)
            warm = len(service.profile.buckets)
            assert service.profile.compiles >= 1
            wave2 = [t.result(120) for t in service.submit_many(cells2)]
            profile = service.profile
        assert all(r.ok and r.backend == "jax" for r in wave1 + wave2)
        assert profile.recompiles == 0
        assert profile.compiles_after(warm) == 0
        assert len(profile.buckets) > warm

    def test_phantom_row_padding_is_inert(self):
        """Partial flushes pad the bucket with phantom rows and lanes;
        each real record must still match its own event reference."""
        from repro.serving import SweepService

        cells = scenario_grid({"l2": listing2_graph()},
                              homogeneous_cluster(3), [4.0, 9.0],
                              ["learned"])
        cells += scenario_grid({"big": layered_dag(5, layers=3, seed=3)},
                               homogeneous_cluster(5), [9.0], ["learned"])
        with SweepService(executor="jax", flush_deadline_s=0.02,
                          bucket_rows=8) as service:
            records = [t.result(120) for t in service.submit_many(cells)]
            assert service.stats().phantom_rows > 0
        for s, rec in zip(cells, records):
            assert rec.ok and rec.backend == "jax"
            ref = simulate(s.graph, list(s.specs), s.bound_w, s.policy)
            assert rec.result.makespan == pytest.approx(ref.makespan,
                                                        rel=1e-3)


# ------------------------------------------------------------- end-to-end
class TestTrainedCheckpoint:
    """Held-out generalization of the bundled checkpoint (numpy only)."""

    def _family_makespans(self):
        fam = random_layered_family(seed=77, n_members=4,
                                    bound_fracs=(0.3, 0.5))
        rows = []
        for m in fam.members:
            for bound in fam.member_bounds(m):
                ms = {p: simulate_batch(m.graph, list(m.specs), [bound],
                                        p)[0].makespan
                      for p in ("equal-share", "heuristic", "learned")}
                rows.append(ms)
        return rows

    def test_beats_equal_share_and_tracks_heuristic(self):
        rows = self._family_makespans()
        vs_eq = [r["learned"] / r["equal-share"] for r in rows]
        vs_heu = [r["learned"] / r["heuristic"] for r in rows]
        # Family means: clearly better than the paper's uniform baseline,
        # at parity with the hand-tuned reclamation heuristic.
        assert np.mean(vs_eq) < 0.97, vs_eq
        assert np.mean(vs_heu) < 1.02, vs_heu
        # Never catastrophically worse than the heuristic on any single
        # held-out scenario.
        assert max(vs_heu) < 1.10, vs_heu
        # And never worse than equal-share by more than a whisker.
        assert max(vs_eq) < 1.06, vs_eq
