"""Integration tests: power-aware trainer (loss goes down, controller
redistributes, failure recovery works) and the serving engine."""

import shutil
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.launch.train import build_trainer
from repro.models import init_params
from repro.serving.engine import ServeEngine


@pytest.fixture()
def ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")


class TestPowerAwareTrainer:
    def test_loss_decreases_and_controller_acts(self, ckpt_dir):
        trainer = build_trainer("llama3-8b", smoke=True, steps=12,
                                hosts=4, batch=4, seq=64,
                                ckpt_dir=ckpt_dir)
        history = trainer.run()
        assert len(history) == 12
        first = np.mean([r.loss for r in history[:3]])
        last = np.mean([r.loss for r in history[-3:]])
        assert last < first, f"loss did not decrease: {first} -> {last}"
        # the controller boosted at least one straggler above equal share
        assert any(max(r.caps_w) > trainer.p_o * 1.01 for r in history)
        # modelled power-aware makespan beats equal share in aggregate
        s = trainer.speedup_summary()
        assert s["speedup"] > 1.0

    def test_power_aware_off_keeps_equal_caps(self, ckpt_dir):
        trainer = build_trainer("qwen1.5-4b", smoke=True, steps=4,
                                hosts=4, batch=4, seq=32,
                                ckpt_dir=ckpt_dir, power_aware=False)
        history = trainer.run()
        for r in history:
            assert all(abs(c - trainer.p_o) < 1e-9 for c in r.caps_w)

    def test_failure_recovery_resumes_from_checkpoint(self, ckpt_dir):
        trainer = build_trainer("llama3-8b", smoke=True, steps=10,
                                hosts=4, batch=4, seq=64,
                                ckpt_dir=ckpt_dir, fail_at=(6,))
        history = trainer.run()
        # ran to completion despite the injected failure
        assert history[-1].step == 9
        # elastic: one host dropped
        assert trainer.n_hosts == 3
        # resumed from the last checkpoint (step 4 with ckpt_every=2)
        steps_seen = [r.step for r in history]
        assert steps_seen.count(6) >= 1

    def test_restart_resumes_step(self, ckpt_dir):
        t1 = build_trainer("qwen1.5-4b", smoke=True, steps=6, hosts=3,
                           batch=4, seq=32, ckpt_dir=ckpt_dir)
        t1.run()
        t2 = build_trainer("qwen1.5-4b", smoke=True, steps=6, hosts=3,
                           batch=4, seq=32, ckpt_dir=ckpt_dir)
        assert t2.start_step == 6  # nothing left to do
        assert t2.run() == []


class TestServeEngine:
    def test_greedy_deterministic(self):
        cfg = get_smoke("llama3-8b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        engine = ServeEngine(cfg, params, max_seq=32, max_batch=2)
        prompts = np.array([[5, 6, 7, 8], [9, 10, 11, 12]], np.int32)
        a = engine.generate(prompts, max_new=6)
        b = engine.generate(prompts, max_new=6)
        np.testing.assert_array_equal(a.new_tokens, b.new_tokens)
        assert a.new_tokens.shape == (2, 6)
        assert (a.new_tokens >= 0).all() and (a.new_tokens < cfg.vocab).all()

    def test_prefill_matches_stepwise_forward(self):
        """Engine prefill+decode must equal teacher-forced forward argmax."""
        from repro.models import forward

        cfg = get_smoke("llama3-8b")
        params = init_params(cfg, jax.random.PRNGKey(1))
        engine = ServeEngine(cfg, params, max_seq=16, max_batch=1)
        prompts = np.array([[3, 4, 5, 6, 7, 8]], np.int32)
        res = engine.generate(prompts, max_new=1)
        import jax.numpy as jnp

        logits, _ = forward(cfg, params, {"tokens": jnp.asarray(prompts)})
        want = int(jnp.argmax(logits[0, -1]))
        assert int(res.new_tokens[0, 0]) == want

    def test_ssm_family_serves(self):
        cfg = get_smoke("xlstm-350m")
        params = init_params(cfg, jax.random.PRNGKey(2))
        engine = ServeEngine(cfg, params, max_seq=24, max_batch=2)
        prompts = np.array([[1, 2, 3], [4, 5, 6]], np.int32)
        out = engine.generate(prompts, max_new=4)
        assert out.new_tokens.shape == (2, 4)

    def test_encoder_rejected(self):
        cfg = get_smoke("hubert-xlarge")
        params = init_params(cfg, jax.random.PRNGKey(3))
        with pytest.raises(ValueError, match="encoder-only"):
            ServeEngine(cfg, params, max_seq=8, max_batch=1)
