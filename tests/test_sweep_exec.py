"""Process-executor start-method regression (ISSUE 7).

Forking a process after JAX initializes its runtime thread pools is a
documented deadlock risk — jax emits ``RuntimeWarning: os.fork() was
called ...`` from its at-fork hook, and a forked worker can hang
inside XLA locks.  Every process pool in :mod:`repro.core.sweep` must
therefore use the ``spawn`` start method.  These tests run with
``RuntimeWarning`` promoted to an error (CI additionally runs them
under ``-W error::RuntimeWarning``), so a regression to the platform
default ``fork`` fails loudly instead of deadlocking a future run.
"""

import importlib.util
import warnings

import pytest

from repro.core import SweepEngine, homogeneous_cluster, listing2_graph
from repro.core.sweep import _process_pool, scenario_grid

HAS_JAX = importlib.util.find_spec("jax") is not None


def _init_jax_threads():
    """Put jax in the dangerous state: runtime initialized, thread
    pools live.  A subsequent ``fork`` is what the spawn fix
    prevents."""
    if HAS_JAX:
        import jax
        import jax.numpy as jnp

        jax.device_get(jnp.ones(4) * 2)


class TestSpawnContext:
    def test_process_pool_uses_spawn(self):
        with _process_pool(max_workers=1) as pool:
            assert pool._mp_context.get_start_method() == "spawn"
            assert pool.submit(max, 2, 3).result(timeout=60) == 3

    def test_sweep_run_emits_no_fork_warning(self):
        _init_jax_threads()
        cells = scenario_grid({"l2": listing2_graph()},
                              homogeneous_cluster(3), [6.0, 9.0],
                              ["equal-share"])
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            result = SweepEngine(executor="process",
                                 max_workers=2).run(cells)
        assert not result.failures
        assert len(result.records) == 2

    def test_engine_map_emits_no_fork_warning(self):
        _init_jax_threads()
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            records = SweepEngine(executor="process", max_workers=2) \
                .map(len, [(1, 2), (3,), ()])
        assert [r.value for r in records] == [2, 1, 0]
        assert all(r.ok for r in records)

    @pytest.mark.skipif(not HAS_JAX, reason="jax not installed")
    def test_process_workers_survive_jax_parent(self):
        """The actual deadlock scenario: jax-initialized parent, ILP
        shared setup in-process, simulation in spawned workers."""
        _init_jax_threads()
        cells = scenario_grid({"l2": listing2_graph()},
                              homogeneous_cluster(3), [6.0],
                              ["equal-share", "oracle"])
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            result = SweepEngine(executor="process",
                                 max_workers=2).run(cells)
        assert not result.failures
        from repro.core import simulate

        ref = simulate(listing2_graph(), homogeneous_cluster(3), 6.0,
                       "equal-share")
        assert result.records[0].result.makespan \
            == pytest.approx(ref.makespan)
