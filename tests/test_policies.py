"""Tests for the pluggable policy subsystem and the batched sweep engine.

Covers the ISSUE-1 acceptance criteria:
  * registry round-trip: every registered policy runs a small diamond
    graph to completion and respects the cluster bound on average;
  * regression: the refactored equal-share / ilp / heuristic policies
    produce makespans *identical* to the pre-refactor simulator (golden
    values captured from the seed at commit c8c2297);
  * ``get_policy("countdown")`` works;
  * the SweepEngine runs batched grids with shared ILP setup, captures
    failures, and bounds power-trace retention via ``trace_every``.
"""

import pytest

from repro.core import (JobDependencyGraph, Scenario, SweepEngine,
                        heterogeneous_cluster, homogeneous_cluster,
                        listing2_graph, listing2_random, ep_like,
                        scenario_grid, simulate, solve_paper_ilp)
from repro.policies import (PowerPolicy, available_policies, get_policy,
                            register_policy)


def tight_bound(specs, frac=0.10):
    return sum(s.lut.idle_w + frac * (s.lut.p_min - s.lut.idle_w)
               for s in specs)


def diamond_graph():
    """Fork-join diamond on 3 nodes: root -> two parallel arms -> join."""
    g = JobDependencyGraph()
    g.add(0, 0, 3.0)
    g.add(1, 0, 6.0, deps=[(0, 0)])
    g.add(2, 0, 2.0, deps=[(0, 0)])
    g.add(0, 1, 2.0, deps=[(0, 0), (1, 0), (2, 0)])
    g.validate()
    return g


# ---------------------------------------------------------------- registry
class TestRegistry:
    def test_expected_policies_registered(self):
        names = available_policies()
        for expected in ("equal-share", "ilp", "heuristic", "countdown",
                         "oracle"):
            assert expected in names

    def test_get_policy_countdown(self):
        """Acceptance: `from repro.policies import get_policy;
        get_policy("countdown")` works."""
        policy = get_policy("countdown")
        assert isinstance(policy, PowerPolicy)
        assert policy.name == "countdown"

    def test_unknown_policy_raises(self):
        with pytest.raises(KeyError, match="unknown policy"):
            get_policy("does-not-exist")

    def test_custom_policy_drop_in(self):
        """A new policy is a decorated class + nothing else."""

        @register_policy("test-noop")
        class NoopPolicy(PowerPolicy):
            name = "test-noop"

        try:
            g = diamond_graph()
            specs = homogeneous_cluster(3)
            r = simulate(g, specs, 9.0, "test-noop")
            assert len(r.job_ends) == len(g)
            assert r.policy == "test-noop"
        finally:
            from repro.policies.registry import _REGISTRY

            _REGISTRY.pop("test-noop", None)

    @pytest.mark.parametrize("name", ["equal-share", "ilp", "heuristic",
                                      "countdown", "oracle"])
    def test_round_trip_diamond(self, name):
        """Every registered policy completes the diamond and stays within
        the cluster bound on average (transient surges above the bound are
        a documented heuristic property, so peak is not asserted)."""
        g = diamond_graph()
        specs = homogeneous_cluster(3)
        P = 0.6 * sum(s.lut.p_max for s in specs)
        r = simulate(g, specs, P, name)
        assert len(r.job_ends) == len(g)
        assert r.makespan > 0
        assert r.avg_power_w <= P + 1e-6
        assert r.energy_j == pytest.approx(r.avg_power_w * r.makespan,
                                           rel=1e-6)


# -------------------------------------------------------------- regression
#: Pre-refactor makespans, captured from the seed simulator (hard-wired
#: policy branches) on listing2_graph + homogeneous_cluster(3).
GOLDEN = {
    2.5: {"equal-share": 162.4153043478261, "ilp": 144.1321202506904,
          "heuristic": 127.67849905804368},
    6.0: {"equal-share": 38.0, "ilp": 33.733333333333334,
          "heuristic": 33.508857142857146},
    12.0: {"equal-share": 25.333333333333332, "ilp": 23.866666666666667,
           "heuristic": 23.019345238095237},
}


class TestRefactorRegression:
    @pytest.mark.parametrize("bound", sorted(GOLDEN))
    def test_golden_makespans(self, bound):
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        gold = GOLDEN[bound]
        eq = simulate(g, specs, bound, "equal-share")
        assert eq.makespan == pytest.approx(gold["equal-share"], rel=1e-12)
        a = solve_paper_ilp(g, specs, bound)
        ilp = simulate(g, specs, bound, "ilp", assignment=a)
        assert ilp.makespan == pytest.approx(gold["ilp"], rel=1e-12)
        heu = simulate(g, specs, bound, "heuristic")
        assert heu.makespan == pytest.approx(gold["heuristic"], rel=1e-12)

    def test_golden_random_graph_heuristic(self):
        """Event-timing identity on a messier graph (debounce + latency)."""
        g = listing2_random(3.0, seed=7)
        specs = homogeneous_cluster(3)
        eq = simulate(g, specs, 4.0, "equal-share")
        heu = simulate(g, specs, 4.0, "heuristic")
        assert eq.makespan == pytest.approx(326.481519167405, rel=1e-12)
        assert heu.makespan == pytest.approx(205.42430309398696, rel=1e-12)

    def test_ilp_policy_self_solves(self):
        """`ilp` without a pre-solved assignment solves at on_start and
        matches the pre-solved path exactly."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        r = simulate(g, specs, 6.0, "ilp")
        assert r.makespan == pytest.approx(GOLDEN[6.0]["ilp"], rel=1e-12)


# ------------------------------------------------------------ new policies
class TestNewPolicies:
    def test_oracle_upper_bounds_heuristic(self):
        """Zero-latency clairvoyant reclamation beats the debounced online
        controller once message latency matters — and, unlike the
        heuristic's documented transient surges (§VII), never draws a
        single joule above the cluster bound."""
        g = ep_like(4, "A")
        specs = heterogeneous_cluster(4)
        oracle = simulate(g, specs, 6.0, "oracle", latency_s=0.5)
        heu = simulate(g, specs, 6.0, "heuristic", latency_s=0.5)
        eq = simulate(g, specs, 6.0, "equal-share", latency_s=0.5)
        assert oracle.makespan <= heu.makespan * 1.001
        assert oracle.makespan < eq.makespan
        assert oracle.over_budget_time == 0.0
        assert heu.over_budget_time >= 0.0  # surging is allowed for heur

    def test_countdown_beats_equal_share_on_ep(self):
        g = ep_like(4, "A")
        specs = heterogeneous_cluster(4)
        P = tight_bound(specs, frac=0.3)
        cd = simulate(g, specs, P, "countdown")
        eq = simulate(g, specs, P, "equal-share")
        assert eq.makespan / cd.makespan > 1.1

    def test_countdown_timeout_filters_short_blocks(self):
        """A countdown longer than every block means no reclamation ever
        fires — makespan degenerates to equal-share's."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        eq = simulate(g, specs, 6.0, "equal-share")
        lazy = simulate(g, specs, 6.0,
                        get_policy("countdown", timeout_s=1e9))
        assert lazy.makespan == pytest.approx(eq.makespan, rel=1e-9)

    def test_bound_change_hook(self):
        """A mid-run power-bound drop slows equal-share down."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        base = simulate(g, specs, 9.0, "equal-share")
        dropped = simulate(g, specs, 9.0, "equal-share",
                           bound_schedule=[(base.makespan / 2, 3.0)])
        assert dropped.makespan > base.makespan * 1.05
        raised = simulate(g, specs, 3.0, "heuristic",
                          bound_schedule=[(1.0, 12.0)])
        tight = simulate(g, specs, 3.0, "heuristic")
        assert raised.makespan < tight.makespan


# ------------------------------------------------------------ sweep engine
class TestSweepEngine:
    def test_grid_runs_and_lookup(self):
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        bounds = [4.0, 9.0]
        scenarios = scenario_grid({"l2": g}, specs, bounds,
                                  ("equal-share", "heuristic"))
        sweep = SweepEngine(max_workers=2).run(scenarios)
        assert len(sweep) == 4 and not sweep.failures
        for P in bounds:
            assert sweep.speedup("l2", "heuristic", P) >= 0.99
        rows = sweep.rows()
        assert {r["policy"] for r in rows} == {"equal-share", "heuristic"}
        csv = sweep.to_csv()
        assert csv.splitlines()[0].startswith("name,policy,bound_w")
        assert len(csv.splitlines()) == 5

    def test_shared_ilp_setup(self):
        """Two ilp scenarios on the same (graph, specs, bound) solve once."""
        import repro.core.ilp as ilp_mod

        calls = {"n": 0}
        real = ilp_mod.solve_paper_ilp

        def counting(*a, **k):
            calls["n"] += 1
            return real(*a, **k)

        g = listing2_graph()
        specs = tuple(homogeneous_cluster(3))
        scenarios = [Scenario(name="a", graph=g, specs=specs, bound_w=6.0,
                              policy="ilp", latency_s=l)
                     for l in (0.05, 0.5)]
        engine = SweepEngine(executor="serial")
        ilp_mod.solve_paper_ilp = counting
        try:
            sweep = engine.run(scenarios)
        finally:
            ilp_mod.solve_paper_ilp = real
        assert not sweep.failures
        assert calls["n"] == 1

    def test_failure_captured_not_raised(self):
        g = listing2_graph()
        specs = tuple(homogeneous_cluster(3))
        scenarios = [
            Scenario(name="ok", graph=g, specs=specs, bound_w=6.0,
                     policy="equal-share"),
            Scenario(name="bad", graph=g, specs=specs, bound_w=6.0,
                     policy="no-such-policy"),
        ]
        sweep = SweepEngine().run(scenarios)
        assert len(sweep.failures) == 1
        assert sweep.failures[0].scenario.name == "bad"
        assert "unknown policy" in sweep.failures[0].error
        assert sweep.result("ok", "equal-share", 6.0).makespan > 0

    def test_policy_instance_not_shared_across_scenarios(self):
        """An instance in several scenarios is deep-copied per run, so
        concurrent/sequential runs can't cross-contaminate its state."""
        from repro.policies import OnlineHeuristicPolicy

        g = listing2_graph()
        specs = homogeneous_cluster(3)
        inst = OnlineHeuristicPolicy()
        sweep = SweepEngine().run(
            scenario_grid({"l2": g}, specs, [2.5, 6.0], [inst]))
        assert not sweep.failures
        for P in (2.5, 6.0):
            ref = simulate(g, specs, P, "heuristic")
            assert sweep.result("l2", "heuristic", P).makespan == \
                pytest.approx(ref.makespan, rel=1e-12)
        assert inst.controller is None  # the original was never run

    def test_process_executor_captures_ilp_failure(self):
        """An infeasible ILP solve is a per-scenario failure in the
        process path too, not a sweep abort."""
        g = listing2_graph()
        specs = tuple(homogeneous_cluster(3))
        scenarios = [
            Scenario(name="ok", graph=g, specs=specs, bound_w=6.0,
                     policy="equal-share"),
            Scenario(name="bad", graph=g, specs=specs, bound_w=0.1,
                     policy="ilp"),  # infeasible bound
        ]
        sweep = SweepEngine(executor="process", max_workers=2).run(scenarios)
        assert len(sweep.failures) == 1
        assert sweep.failures[0].scenario.name == "bad"
        assert sweep.result("ok", "equal-share", 6.0).makespan > 0

    def test_map_captures_errors(self):
        engine = SweepEngine()
        recs = engine.map(lambda x: 1 / x, [2, 0, 4], label=str)
        assert [r.ok for r in recs] == [True, False, True]
        assert recs[0].value == 0.5 and "ZeroDivision" in recs[1].error

    def test_trace_every_bounds_retention(self):
        g = ep_like(3, "A")
        specs = homogeneous_cluster(3)
        P = tight_bound(specs, frac=0.3)
        full = simulate(g, specs, P, "heuristic", trace_every=0.0)
        sampled = simulate(g, specs, P, "heuristic", trace_every=10.0)
        off = simulate(g, specs, P, "heuristic", trace_every=None)
        assert len(full.power_trace) > len(sampled.power_trace) > 0
        assert off.power_trace == []
        # sampling must not perturb the physics
        assert sampled.makespan == pytest.approx(full.makespan, rel=1e-12)
        assert off.energy_j == pytest.approx(full.energy_j, rel=1e-12)

    def test_sweep_scenarios_drop_traces_by_default(self):
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        sweep = SweepEngine().run(scenario_grid({"l2": g}, specs, [6.0],
                                                ("equal-share",)))
        assert sweep.result("l2", "equal-share", 6.0).power_trace == []
