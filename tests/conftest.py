"""Collection guard for the optional jax dependency.

``jax`` moved to the ``[jax]`` optional-dependency group (ISSUE 3): the
core paper library (graph / power / ilp / simulators / sweep) runs on
numpy + scipy alone, so tier-1 must pass in an environment without jax.
Modules that exercise the jax workload zoo, the kernels, or the
compiled backend are skipped at collection time when jax is absent;
jax-aware suites that guard internally (``test_batchsim_diff``,
``test_jax_backend``) handle their own skips.
"""

import importlib.util

if importlib.util.find_spec("jax") is None:
    collect_ignore = [
        "test_attention_moe.py",
        "test_diff_grad.py",        # jax.grad is the object under test
        "test_dryrun_cli.py",       # subprocess imports repro.launch
        "test_hlo_roofline.py",
        "test_kernels.py",
        "test_models_smoke.py",
        "test_runtime_serving.py",
        "test_ssm_xlstm.py",
        "test_substrates.py",
    ]
