"""Blocked (flash-style) XLA attention vs the naive path, MoE dispatch
properties, and TraceBuilder validation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (blocked_attend, gqa_attend,
                                    gqa_scores_mask)
from repro.models.moe import capacity, moe_ffn, moe_init
from repro.core.workloads import TraceBuilder

KEY = jax.random.PRNGKey(11)


class TestBlockedAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("window", [0, 64])
    def test_matches_naive(self, causal, window):
        B, S, H, Hkv, dh = 2, 256, 4, 2, 32
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, dh))
        k = jax.random.normal(ks[1], (B, S, Hkv, dh))
        v = jax.random.normal(ks[2], (B, S, Hkv, dh))
        pos = jnp.arange(S)
        positions = jnp.broadcast_to(pos[None], (B, S))
        keep = gqa_scores_mask(positions, positions, causal, window)
        want = gqa_attend(q, k, v, keep if (causal or window) else None)
        got = blocked_attend(q, k, v, pos, pos, causal, window,
                             block_q=64, block_kv=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_block_invariance(self):
        B, S, H, dh = 1, 128, 2, 16
        q = jax.random.normal(KEY, (B, S, H, dh))
        pos = jnp.arange(S)
        a = blocked_attend(q, q, q, pos, pos, True, 0, 32, 32)
        b = blocked_attend(q, q, q, pos, pos, True, 0, 128, 64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


class TestMoE:
    def test_capacity_formula(self):
        assert capacity(tokens=4096, n_experts=128, top_k=2,
                        capacity_factor=1.25) == 80
        assert capacity(8, 64, 2, 1.0) == 8  # floor + x8 rounding

    def test_all_tokens_routed_with_big_capacity(self):
        """With generous capacity nothing is dropped: output == weighted
        mix of expert outputs for every token (no zero rows)."""
        d, ff, E, k = 16, 32, 4, 2
        params = moe_init(KEY, d, ff, E, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
        out, aux = moe_ffn(params, x, n_experts=E, top_k=k,
                           capacity_factor=8.0)
        assert out.shape == x.shape
        assert float(jnp.min(jnp.sum(jnp.abs(out), axis=-1))) > 0
        assert float(aux) >= 1.0 - 1e-5  # aux lower bound is 1 (balanced)

    def test_capacity_drops_reduce_output(self):
        """Tiny capacity drops tokens: dropped rows produce zero output
        (the residual passes through at the block level)."""
        d, ff, E, k = 8, 16, 2, 1
        params = moe_init(KEY, d, ff, E, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, d))
        full, _ = moe_ffn(params, x, n_experts=E, top_k=k,
                          capacity_factor=8.0)
        tight, _ = moe_ffn(params, x, n_experts=E, top_k=k,
                           capacity_factor=0.25)
        n_zero = int(jnp.sum(jnp.sum(jnp.abs(tight), axis=-1) < 1e-9))
        assert n_zero > 0
        assert float(jnp.max(jnp.abs(full))) > 0


class TestTraceBuilder:
    def test_collective_membership_mismatch_raises(self):
        tb = TraceBuilder(3)
        for n in range(3):
            tb.compute(n, 1.0)
        tb.collective("allreduce", [0, 1, 2])
        # node 0 does an extra allreduce the others never reach
        tb.compute(0, 1.0)
        tb._end_with(0, ("coll", "allreduce", (0, 1, 2)))
        with pytest.raises(ValueError, match="mismatched"):
            tb.build()

    def test_unmatched_send_recv_raises(self):
        tb = TraceBuilder(2)
        tb.compute(0, 1.0)
        tb.send(0, 1)
        with pytest.raises(ValueError, match="unmatched"):
            tb.build()

    def test_ring_graph_depths(self):
        """A 3-node ring serialises: depths increase around the ring."""
        tb = TraceBuilder(3)
        for n in range(3):
            tb.compute(n, 1.0)
        tb.collective("barrier", [0, 1, 2])
        tb.compute(0, 1.0)
        tb.send(0, 1)
        tb.compute(1, 1.0)
        tb.recv(1, 0)
        tb.compute(1, 0.5)
        tb.send(1, 2)
        tb.compute(2, 1.0)
        tb.recv(2, 1)
        g = tb.build()
        g.validate()
        depths = g.max_depths()
        # node2's post-recv job deeper than node1's post-recv job
        n1_max = max(d for (n, _), d in depths.items() if n == 1)
        n2_max = max(d for (n, _), d in depths.items() if n == 2)
        assert n2_max >= n1_max
