"""Numerical correctness of the sequence-mixing primitives against naive
recurrence oracles, plus chunk-size invariance (the property that makes
the chunked SSD algorithm trustworthy at 500k context).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property-based tests skip without hypothesis
    from _hyp_stub import given, settings, st

from repro.models.ssm import ssm_decode, ssm_forward, ssm_init
from repro.models.xlstm import (_mlstm_cell_parallel, mlstm_decode,
                                mlstm_forward, mlstm_init)

KEY = jax.random.PRNGKey(42)


def naive_ssd(x, a, dt, Bm, Cm, D):
    """Oracle: h_t = exp(a_t) h_{t-1} + dt_t B_t (x) x_t ; y = C_t.h + D x."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    h = np.zeros((B, H, P, N), np.float64)
    ys = []
    for t in range(S):
        h = np.exp(a[:, t])[:, :, None, None] * h + \
            np.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], Bm[:, t])
        ys.append(np.einsum("bn,bhpn->bhp", Cm[:, t], h)
                  + D[None, :, None] * x[:, t])
    return np.stack(ys, axis=1)


class TestSSD:
    @pytest.mark.parametrize("chunk", [4, 8, 16, 64])
    def test_chunked_matches_naive(self, chunk):
        B, S, H, P, N = 2, 24, 3, 4, 5
        rng = np.random.default_rng(0)
        x = rng.normal(size=(B, S, H, P)).astype(np.float32)
        a = -np.abs(rng.normal(size=(B, S, H))).astype(np.float32) * 0.3
        dt = np.abs(rng.normal(size=(B, S, H))).astype(np.float32)
        Bm = rng.normal(size=(B, S, N)).astype(np.float32)
        Cm = rng.normal(size=(B, S, N)).astype(np.float32)
        D = rng.normal(size=(H,)).astype(np.float32)

        # replicate the core of ssm_forward's chunked math directly
        from repro.models import ssm as ssm_mod

        Q = chunk
        n_chunks = (S + Q - 1) // Q
        pad = n_chunks * Q - S

        def chunked(x, a, dt, Bm, Cm):
            xh, af, dtf = (jnp.asarray(v) for v in (x, a, dt))
            Bf, Cf = jnp.asarray(Bm), jnp.asarray(Cm)
            if pad:
                xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
                af = jnp.pad(af, ((0, 0), (0, pad), (0, 0)))
                dtf = jnp.pad(dtf, ((0, 0), (0, pad), (0, 0)))
                Bf = jnp.pad(Bf, ((0, 0), (0, pad), (0, 0)))
                Cf = jnp.pad(Cf, ((0, 0), (0, pad), (0, 0)))
            K = n_chunks
            xh = xh.reshape(B, K, Q, H, P)
            Bf = Bf.reshape(B, K, Q, N)
            Cf = Cf.reshape(B, K, Q, N)
            af = af.reshape(B, K, Q, H)
            dtf = dtf.reshape(B, K, Q, H)
            csum = jnp.cumsum(af, axis=2)
            li = csum[:, :, :, None, :] - csum[:, :, None, :, :]
            mask = jnp.tril(jnp.ones((Q, Q), bool))
            L = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
            cb = jnp.einsum("bkin,bkjn->bkij", Cf, Bf)
            y_intra = jnp.einsum("bkij,bkijh,bkjh,bkjhp->bkihp",
                                 cb, L, dtf, xh)
            decay_to_end = jnp.exp(csum[:, :, -1:, :] - csum)
            chunk_state = jnp.einsum("bkjn,bkjh,bkjh,bkjhp->bkhpn",
                                     Bf, decay_to_end, dtf, xh)
            chunk_decay = jnp.exp(csum[:, :, -1, :])

            def carry(h, inp):
                stt, dec = inp
                return h * dec[..., None, None] + stt, h

            h0 = jnp.zeros((B, H, P, N), jnp.float32)
            _, h_in = jax.lax.scan(
                carry, h0, (jnp.moveaxis(chunk_state, 1, 0),
                            jnp.moveaxis(chunk_decay, 1, 0)))
            h_in = jnp.moveaxis(h_in, 0, 1)
            y_inter = jnp.einsum("bkin,bkih,bkhpn->bkihp",
                                 Cf, jnp.exp(csum), h_in)
            y = (y_intra + y_inter).reshape(B, K * Q, H, P)[:, :S]
            return y + jnp.asarray(D)[None, None, :, None] * jnp.asarray(
                x)

        got = np.asarray(chunked(x, a, dt, Bm, Cm))
        want = naive_ssd(x, a, dt, Bm, Cm, D)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_forward_decode_consistency(self):
        """Prefill then stepwise decode must produce identical outputs."""
        d_model, S, B = 32, 12, 2
        expand, state_dim, head_dim, conv_w = 2, 8, 8, 4
        params = ssm_init(KEY, d_model, expand=expand, state_dim=state_dim,
                          head_dim=head_dim, conv_width=conv_w,
                          dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d_model))
        full = ssm_forward(params, x, expand=expand, state_dim=state_dim,
                           head_dim=head_dim, chunk=4)

        d_inner = expand * d_model
        Dc = d_inner + 2 * state_dim
        H = d_inner // head_dim
        conv_state = jnp.zeros((B, conv_w - 1, Dc))
        ssm_state = jnp.zeros((B, H, head_dim, state_dim))
        outs = []
        for t in range(S):
            o, conv_state, ssm_state = ssm_decode(
                params, x[:, t: t + 1], conv_state, ssm_state,
                expand=expand, state_dim=state_dim, head_dim=head_dim)
            outs.append(o)
        step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                                   rtol=2e-3, atol=2e-3)


class TestMLSTM:
    def naive_mlstm(self, q, k, v, log_i, log_f):
        """Oracle stabilised recurrence (xLSTM paper eqs. 19-27)."""
        B, S, H, dh = q.shape
        C = np.zeros((B, H, dh, dh), np.float64)
        n = np.zeros((B, H, dh), np.float64)
        m = np.full((B, H), -np.inf)
        outs = []
        qs = np.asarray(q, np.float64) / np.sqrt(dh)
        for t in range(S):
            m_new = np.maximum(log_f[:, t] + m, log_i[:, t])
            i_g = np.exp(log_i[:, t] - m_new)
            f_g = np.exp(log_f[:, t] + m - m_new)
            C = f_g[..., None, None] * C + i_g[..., None, None] * \
                np.einsum("bhk,bhv->bhkv", k[:, t], v[:, t])
            n = f_g[..., None] * n + i_g[..., None] * k[:, t]
            m = m_new
            num = np.einsum("bhk,bhkv->bhv", qs[:, t], C)
            den = np.maximum(np.abs(np.einsum("bhk,bhk->bh", qs[:, t], n)),
                             np.exp(-m))
            outs.append(num / den[..., None])
        return np.stack(outs, axis=1)

    def test_parallel_matches_recurrence(self):
        B, S, H, dh = 2, 16, 2, 8
        rng = np.random.default_rng(3)
        q = rng.normal(size=(B, S, H, dh)).astype(np.float32)
        k = rng.normal(size=(B, S, H, dh)).astype(np.float32)
        v = rng.normal(size=(B, S, H, dh)).astype(np.float32)
        log_i = rng.normal(size=(B, S, H)).astype(np.float32)
        log_f = np.log(1 / (1 + np.exp(-rng.normal(
            size=(B, S, H)).astype(np.float32) - 2)))
        got = np.asarray(_mlstm_cell_parallel(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(log_i), jnp.asarray(log_f)))
        want = self.naive_mlstm(q, k, v, log_i, log_f)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_block_forward_decode_consistency(self):
        d_model, S, B, H = 32, 10, 2, 2
        params = mlstm_init(KEY, d_model, H, 2.0, 4, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(2), (B, S, d_model))
        full = mlstm_forward(params, x, H)
        d_in = int(2.0 * d_model)
        dh = d_in // H
        state = {"C": jnp.zeros((B, H, dh, dh)),
                 "n": jnp.zeros((B, H, dh)),
                 "m": jnp.full((B, H), -1e30),
                 "conv": jnp.zeros((B, 3, d_in))}
        outs = []
        for t in range(S):
            o, state = mlstm_decode(params, x[:, t: t + 1], state, H)
            outs.append(o)
        step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                                   rtol=2e-3, atol=2e-3)


class TestMLSTMChunked:
    def _rand(self, S):
        rng = np.random.default_rng(7)
        B, H, dh = 2, 3, 8
        q = rng.normal(size=(B, S, H, dh)).astype(np.float32)
        k = rng.normal(size=(B, S, H, dh)).astype(np.float32)
        v = rng.normal(size=(B, S, H, dh)).astype(np.float32)
        log_i = rng.normal(size=(B, S, H)).astype(np.float32)
        log_f = np.log(1 / (1 + np.exp(
            -rng.normal(size=(B, S, H)).astype(np.float32) - 2)))
        return q, k, v, log_i, log_f

    @pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (48, 16)])
    def test_chunked_matches_parallel(self, S, chunk):
        from repro.models.xlstm import (_mlstm_cell_chunked,
                                        _mlstm_cell_parallel)

        q, k, v, li, lf = self._rand(S)
        want = np.asarray(_mlstm_cell_parallel(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(li), jnp.asarray(lf)))
        got = np.asarray(_mlstm_cell_chunked(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(li), jnp.asarray(lf), chunk=chunk))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_chunk_size_invariance(self):
        from repro.models.xlstm import _mlstm_cell_chunked

        q, k, v, li, lf = self._rand(64)
        a = np.asarray(_mlstm_cell_chunked(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(li), jnp.asarray(lf), chunk=8))
        b = np.asarray(_mlstm_cell_chunked(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(li), jnp.asarray(lf), chunk=32))
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
