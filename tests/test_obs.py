"""Observability layer (ISSUE 10): tracer, metrics, timelines.

Covers the four contracts the unified layer promises:

* **Chrome trace schema** — every emitted event is a valid
  ``trace_event`` dict (``ph``/``ts``/``pid``/``tid``), complete
  events nest monotonically per lane, and a full multi-layer replay
  lands its layers on disjoint track ids.
* **Near-zero disabled cost** — the disabled module-level path returns
  one shared singleton (identity, not equality), allocates nothing,
  and instrumented runs emit an event count bounded by *buckets*, not
  cells (a call-count budget, deliberately not a wall-clock assert).
* **Metrics registry** — labeled counters/gauges/histograms with
  percentiles that agree with :func:`repro.serving.stream.percentile`,
  a stable snapshot schema, and deterministic bounded reservoirs.
* **Power timelines** — counter samples from a ``node_trace=True``
  simulation never exceed the bound, and the bound line rides along.
"""

import json
import threading
import tracemalloc

import pytest

from repro.core import (SweepEngine, homogeneous_cluster,
                        listing2_graph, scenario_grid, simulate)
from repro.obs import Tracer, trace
from repro.obs.metrics import (DEFAULT_RESERVOIR, Histogram,
                               MetricsRegistry)
from repro.obs.timeline import power_tracks, sim_tracks
from repro.serving import SweepService, percentile, poisson_replay


@pytest.fixture
def tracer():
    """A fresh installed tracer, uninstalled afterwards."""
    t = trace.install(Tracer())
    yield t
    trace.uninstall()


def grid(bounds=(6.0, 9.0), policies=("equal-share",), **kwargs):
    return scenario_grid({"l2": listing2_graph()},
                         homogeneous_cluster(3), list(bounds),
                         list(policies), **kwargs)


# --------------------------------------------------------------- schema
REQUIRED_KEYS = {"ph", "name", "ts", "pid", "tid"}


def assert_valid_events(events):
    for ev in events:
        required = (REQUIRED_KEYS - {"ts"} if ev.get("ph") == "M"
                    else REQUIRED_KEYS)
        missing = required - set(ev)
        assert not missing, f"{ev} lacks {missing}"
        assert isinstance(ev["pid"], int) and ev["pid"] >= 1
        assert isinstance(ev["tid"], int) and ev["tid"] >= 0
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0
        elif ev["ph"] == "i":
            assert ev["s"] == "t"
        elif ev["ph"] == "C":
            assert all(isinstance(v, float)
                       for v in ev["args"].values())
        elif ev["ph"] in ("b", "e"):
            assert ev["id"]


class TestTracerSchema:
    def test_all_phases_valid(self, tracer):
        with trace.span("outer", cat="t", track="a", args={"k": 1}):
            trace.instant("mark", track="a")
        trace.counter("load", {"x": 1.0, "y": 2.0}, track="b", ts=0.5)
        trace.complete("done", 0.0, 0.25, track="b", ts=1.0)
        trace.async_begin("req", "r1", track="a")
        trace.async_end("req", "r1", track="a")
        events = tracer.events()
        assert_valid_events(events)
        assert {"M", "X", "i", "C", "b", "e"} <= {e["ph"]
                                                 for e in events}

    def test_json_roundtrip(self, tracer, tmp_path):
        with trace.span("s", track="a"):
            pass
        path = tmp_path / "trace.json"
        tracer.write(str(path))
        parsed = json.loads(path.read_text())
        assert isinstance(parsed, list)
        assert_valid_events(parsed)
        assert parsed == tracer.events()

    def test_track_and_lane_metadata(self, tracer):
        trace.instant("a", track="service")
        trace.instant("b", track="engine", lane="worker-1")
        names = {(e["args"]["name"], e["pid"]) for e in tracer.events()
                 if e["ph"] == "M" and e["name"] == "process_name"}
        assert {n for n, _ in names} == {"service", "engine"}
        pids = tracer.track_ids()
        assert pids["service"] != pids["engine"]
        lanes = [e for e in tracer.events()
                 if e["ph"] == "M" and e["name"] == "thread_name"]
        assert any(e["args"]["name"] == "worker-1" for e in lanes)

    def test_simulated_ts_in_microseconds(self, tracer):
        trace.complete("job", 0.0, 2.0, track="cluster", ts=1.5)
        ev = [e for e in tracer.events() if e["ph"] == "X"][0]
        assert ev["ts"] == pytest.approx(1.5e6)
        assert ev["dur"] == pytest.approx(2.0e6)

    def test_spans_nest_monotonically(self, tracer):
        with trace.span("outer", track="a"):
            with trace.span("mid", track="a"):
                with trace.span("inner", track="a"):
                    pass
        xs = {e["name"]: e for e in tracer.events() if e["ph"] == "X"}
        assert len({(e["pid"], e["tid"]) for e in xs.values()}) == 1
        for child, parent in (("inner", "mid"), ("mid", "outer")):
            c, p = xs[child], xs[parent]
            assert c["ts"] >= p["ts"]
            assert c["ts"] + c["dur"] <= p["ts"] + p["dur"] + 1e-6

    def test_threads_get_distinct_lanes(self, tracer):
        def emit():
            trace.instant("tick", track="svc")

        threads = [threading.Thread(target=emit, name=f"lane{i}")
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        ticks = [e for e in tracer.events()
                 if e["ph"] == "i" and e["name"] == "tick"]
        assert len({e["tid"] for e in ticks}) == 4

    def test_installed_empty_tracer_is_truthy(self):
        assert bool(Tracer())
        assert len(Tracer()) == 0


# --------------------------------------------------- disabled-path cost
class TestDisabledPath:
    def test_disabled_span_is_shared_singleton(self):
        assert not trace.enabled()
        s1, s2 = trace.span("a", track="x"), trace.span("b")
        assert s1 is s2                    # identity: zero allocation
        with s1:
            pass

    def test_disabled_emitters_allocate_nothing(self):
        assert not trace.enabled()
        args = {"k": 1}
        values = {"x": 1.0}
        trace.instant("warm", args=args)   # warm up any lazy state
        tracemalloc.start()
        try:
            tracemalloc.clear_traces()
            for _ in range(1000):
                trace.complete("n", 0.0, 0.0, args=args)
                trace.instant("n", args=args)
                trace.counter("n", values)
                with trace.span("n", args=args):
                    pass
            current, peak = tracemalloc.get_traced_memory()
        finally:
            tracemalloc.stop()
        # the loop itself allocates nothing; allow slack for
        # interpreter-internal bookkeeping
        assert peak < 4096, f"disabled tracing allocated {peak}B"

    def test_event_count_budget_is_per_bucket_not_per_cell(self, tracer):
        # a call-count budget, not a wall-clock assert: tracing a sweep
        # must emit O(buckets) events, never O(cells)
        cells = grid(bounds=(2.5, 6.0, 9.0, 12.0))
        result = SweepEngine(executor="vector").run(cells)
        assert not result.failures
        events = [e for e in tracer.events() if e["ph"] != "M"]
        buckets = sum(1 for e in events if e["name"] == "bucket")
        assert buckets >= 1
        assert len(events) <= 4 * buckets + 4


# ------------------------------------------------------ merged replay
class TestMergedReplay:
    def test_layers_land_on_disjoint_tracks(self, tracer):
        cells = grid()
        with SweepService(executor="vector",
                          flush_deadline_s=0.02) as svc:
            report = poisson_replay(svc, cells, rate_hz=200.0, seed=0)
        assert not report.failures
        r = simulate(listing2_graph(), homogeneous_cluster(3), 9.0,
                     node_trace=True)
        sim_tracks(r, 9.0, label="l2")
        pids = tracer.track_ids()
        assert {"service", "engine", "power:l2"} <= set(pids)
        assert len(set(pids.values())) == len(pids)   # no collisions
        assert_valid_events(tracer.events())

    def test_service_emits_request_lifecycle(self, tracer):
        cells = grid()
        with SweepService(executor="vector",
                          flush_deadline_s=0.02) as svc:
            for t in svc.submit_many(cells):
                t.result(timeout=60)
        names = {(e["ph"], e["name"]) for e in tracer.events()}
        assert ("b", "request") in names
        assert ("e", "request") in names
        assert ("i", "flush") in names
        begins = [e for e in tracer.events() if e["ph"] == "b"]
        ends = [e for e in tracer.events() if e["ph"] == "e"]
        assert {e["id"] for e in begins} == {e["id"] for e in ends}


# ------------------------------------------------------ power timeline
class TestPowerTimeline:
    def test_counter_sums_stay_under_bound(self, tracer):
        bound = 9.0
        r = simulate(listing2_graph(), homogeneous_cluster(3), bound,
                     node_trace=True)
        assert r.node_power_trace, "node_trace=True must record nodes"
        n = sim_tracks(r, bound, label="l2")
        assert n >= len(r.node_power_trace)
        power = [e for e in tracer.events()
                 if e["ph"] == "C" and e["name"] == "power_w"]
        assert power
        for ev in power:
            assert sum(ev["args"].values()) <= bound + 1e-6
        bound_line = [e for e in tracer.events()
                      if e["ph"] == "C" and e["name"] == "bound_w"]
        assert all(e["args"]["bound"] == bound for e in bound_line)

    def test_job_spans_cover_every_start(self, tracer):
        r = simulate(listing2_graph(), homogeneous_cluster(3), 9.0,
                     node_trace=True)
        sim_tracks(r, 9.0, label="l2")
        jobs = [e for e in tracer.events()
                if e["ph"] == "X" and e["cat"] == "job"]
        assert len(jobs) == len(r.job_starts)

    def test_freq_track_with_specs(self, tracer):
        specs = homogeneous_cluster(3)
        r = simulate(listing2_graph(), specs, 9.0, node_trace=True)
        sim_tracks(r, 9.0, label="l2", specs=specs)
        freq = [e for e in tracer.events()
                if e["ph"] == "C" and e["name"] == "freq_mhz"]
        assert len(freq) == len(r.node_power_trace)
        f_max = specs[0].lut.f_max
        for ev in freq:
            assert all(0.0 <= v <= f_max for v in ev["args"].values())

    def test_fallback_to_cluster_total(self, tracer):
        r = simulate(listing2_graph(), homogeneous_cluster(3), 9.0)
        assert not r.node_power_trace
        sim_tracks(r, 9.0, label="l2")
        power = [e for e in tracer.events() if e["name"] == "power_w"]
        assert power and all(set(e["args"]) == {"cluster"}
                             for e in power)

    def test_explicit_tracer_beats_installed(self):
        mine = Tracer()
        n = power_tracks([(0.0, {"a": 1.0})], 2.0, tracer=mine)
        assert n == 3 and len(mine) > 0        # samples + bound steps

    def test_disabled_returns_zero(self):
        assert not trace.enabled()
        assert power_tracks([(0.0, {"a": 1.0})], 2.0) == 0


# ------------------------------------------------------------- metrics
class TestMetrics:
    def test_counter_labels(self):
        reg = MetricsRegistry()
        c = reg.counter("flushes")
        c.inc(cause="full")
        c.inc(cause="full")
        c.inc(cause="deadline")
        assert c.value(cause="full") == 2
        assert c.value(cause="deadline") == 1
        assert c.value(cause="never") == 0
        assert c.total() == 3

    def test_gauge_set_and_add(self):
        reg = MetricsRegistry()
        g = reg.gauge("depth")
        g.set(4)
        g.add(-1)
        assert g.value() == 3
        g.set(10, node="n1")
        assert g.value(node="n1") == 10

    def test_accessors_idempotent_and_kind_checked(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_histogram_matches_serving_percentile(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat")
        values = [0.7, 0.1, 0.9, 0.3, 0.5]
        for v in values:
            h.observe(v)
        for p in (50, 90, 99):
            assert h.pct(p) == percentile(values, p)
        assert h.pct(50, phase="steady") is None

    def test_histogram_reservoir_bounded_and_deterministic(self):
        def fill():
            h = Histogram("h", threading.Lock(), reservoir=64)
            for i in range(5000):
                h.observe(float(i))
            return h

        a, b = fill(), fill()
        assert a.count() == 5000
        series = a._series[""]
        assert len(series.samples) == 64
        assert series.lo == 0.0 and series.hi == 4999.0
        assert a._series[""].samples == b._series[""].samples

    def test_snapshot_schema(self):
        reg = MetricsRegistry()
        reg.counter("c").inc(cause="full")
        reg.gauge("g").set(2.0)
        reg.histogram("h").observe(1.0)
        snap = reg.snapshot()
        assert set(snap) == {"counters", "gauges", "histograms"}
        assert snap["counters"]["c"] == {"cause=full": 1.0}
        assert snap["gauges"]["g"] == {"": 2.0}
        entry = snap["histograms"]["h"][""]
        assert set(entry) == {"count", "sum", "min", "max",
                              "p50", "p90", "p99"}
        json.dumps(snap)                      # JSON-ready end to end
        assert DEFAULT_RESERVOIR >= 1024


# --------------------------------------------------- service + metrics
class TestServiceMetrics:
    def test_stats_quote_registry_percentiles(self):
        cells = grid(bounds=(2.5, 6.0, 12.0))
        with SweepService(executor="vector",
                          flush_deadline_s=0.02) as svc:
            for t in svc.submit_many(cells):
                t.result(timeout=60)
            stats = svc.stats()
        assert stats.completed == len(cells)
        assert stats.latency_p50_s is not None
        assert stats.latency_p50_s <= stats.latency_p99_s
        assert stats.latency_p50_s == svc.latency_pct(50)
        d = stats.to_dict()
        assert d["latency_p50_s"] == stats.latency_p50_s
        assert stats.flushed_full + stats.flushed_deadline \
            == stats.buckets

    def test_phase_label_excludes_warmup(self):
        cells = grid()
        with SweepService(executor="vector",
                          flush_deadline_s=0.02) as svc:
            for t in svc.submit_many(cells):
                t.result(timeout=60)
            assert svc.latency_pct(50, phase="steady") is None
            svc.set_phase("steady")
            for t in svc.submit_many(cells):
                t.result(timeout=60)
            h = svc.metrics.histogram("serve_latency_s")
            assert h.count(phase="steady") == len(cells)
            assert h.count() == 2 * len(cells)

    def test_injected_registry_is_used(self):
        reg = MetricsRegistry()
        cells = grid()
        with SweepService(executor="vector", flush_deadline_s=0.02,
                          metrics=reg) as svc:
            for t in svc.submit_many(cells):
                t.result(timeout=60)
        assert reg.counter("serve_completed").total() == len(cells)


# ------------------------------------------------- jax: tracing + jit
class TestJaxTracing:
    def test_compile_once_survives_tracing(self, tracer):
        from repro.backends.jax import HAS_JAX

        if not HAS_JAX:
            pytest.skip("jax not installed")
        cells = grid(bounds=(2.5, 6.0, 12.0))
        with SweepService(executor="jax",
                          flush_deadline_s=0.02) as svc:
            for t in svc.submit_many(cells):
                t.result(timeout=300)
            svc.drain(timeout=60)
            warm = len(svc.profile.buckets)
            for t in svc.submit_many(cells):
                t.result(timeout=300)
            prof = svc.profile
        assert prof.recompiles == 0
        assert prof.compiles_after(warm) == 0
        names = [e["name"] for e in tracer.events() if e["ph"] == "X"]
        assert "pack" in names
        # every jit compile shows up as exactly one "compile" span
        assert names.count("compile") == prof.compiles
