"""Online heuristic (Algorithm 1) + simulator tests, validating the
paper's qualitative claims:

* speedup > 1 at tight bounds, -> 1.0 as the bound relaxes (Fig. 8);
* speedup grows with execution-time stddev (Fig. 9);
* EP-like >> IS-like > CG-like ~ 1.0 (Figs. 11-13), heuristic never
  catastrophically harmful on CG (paper worst case 0.98);
* heuristic avg power slightly above equal-share (§VII-C observation);
* debounce suppresses report pairs shorter than the break-even RTT.
"""

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property-based tests skip without hypothesis
    from _hyp_stub import given, settings, st

from repro.core import (NodeState, PowerDistributionController, ReportManager,
                        blocked_report, cg_like, compare_policies, ep_like,
                        heterogeneous_cluster, homogeneous_cluster, is_like,
                        listing2_graph, listing2_random, listing2_uniform,
                        moe_step_graph, pipeline_graph, running_report,
                        simulate)
from repro.core.power import DUTY_FLOOR


def tight_bound(specs, frac=0.10):
    return sum(s.lut.idle_w + frac * (s.lut.p_min - s.lut.idle_w)
               for s in specs)


def mid_bound(specs):
    return 0.5 * sum(s.lut.p_max for s in specs)


# ----------------------------------------------------------- Algorithm 1
class TestController:
    def test_rank_proportional_distribution(self):
        """A node blocking two others gets twice the boost (Alg. 1 l.41)."""
        ctrl = PowerDistributionController(cluster_bound_w=12.0, n_nodes=4)
        ctrl.process_message(running_report(0, 0.0))
        ctrl.process_message(running_report(1, 0.0))
        ctrl.process_message(blocked_report(2, {0}, 1.0, 0.0))
        out = ctrl.process_message(blocked_report(3, {0}, 1.0, 0.0))
        grants = {m.node: m.power_bound_w for m in out}
        # node0 blocks two nodes (rank 2), node1 none (rank 0)
        assert grants[0] == pytest.approx(3.0 + 2.0)
        assert 1 not in grants or grants[1] == pytest.approx(3.0)

    def test_budget_conservation_without_boosted_blockers(self):
        """Granted running power + idle draw <= P when blocked nodes were
        at their equal share before blocking."""
        specs = homogeneous_cluster(4)
        P = 8.0
        ctrl = PowerDistributionController(P, 4, specs=specs)
        for n in range(4):
            ctrl.process_message(running_report(n, 0.0))
        p_o = P / 4
        pg = p_o - specs[0].lut.idle_w
        ctrl.process_message(blocked_report(3, {0}, pg, 1.0))
        total = ctrl.budget_in_use()
        assert total <= P + 1e-9

    def test_unblock_restores_equal_share(self):
        ctrl = PowerDistributionController(9.0, 3)
        ctrl.process_message(running_report(0, 0.0))
        ctrl.process_message(running_report(1, 0.0))
        ctrl.process_message(blocked_report(2, {0}, 2.0, 0.0))
        out = ctrl.process_message(running_report(2, 1.0))
        grants = {m.node: m.power_bound_w for m in out}
        assert all(g == pytest.approx(3.0) for g in grants.values())

    def test_unknown_blocker_materialised(self):
        ctrl = PowerDistributionController(9.0, 3)
        out = ctrl.process_message(blocked_report(0, {7}, 2.0, 0.0))
        grants = {m.node: m.power_bound_w for m in out}
        assert grants[7] == pytest.approx(3.0 + 2.0)

    def test_t_zero_splits_equally(self):
        """Blocked on an external node: Algorithm 1 would divide by zero;
        we split the budget equally among running nodes (documented)."""
        ctrl = PowerDistributionController(9.0, 3)
        ctrl.process_message(running_report(0, 0.0))
        ctrl.process_message(running_report(1, 0.0))
        out = ctrl.process_message(blocked_report(2, set(), 2.0, 0.0))
        grants = {m.node: m.power_bound_w for m in out}
        assert grants[0] == pytest.approx(4.0)
        assert grants[1] == pytest.approx(4.0)


class TestReportManager:
    def test_fast_pair_suppressed(self):
        rm = ReportManager(node=0, breakeven_s=0.1)
        assert rm.offer(blocked_report(0, {1}, 1.0, 0.0), 0.0) == []
        assert rm.offer(running_report(0, 0.05), 0.05) == []
        assert rm.suppressed == 2
        assert rm.poll(1.0) == []  # nothing left

    def test_slow_block_reported(self):
        rm = ReportManager(node=0, breakeven_s=0.1)
        rm.offer(blocked_report(0, {1}, 1.0, 0.0), 0.0)
        out = rm.poll(0.1)
        assert len(out) == 1 and out[0].state == NodeState.BLOCKED

    def test_same_state_update_replaces(self):
        rm = ReportManager(node=0, breakeven_s=0.1)
        rm.offer(blocked_report(0, {1}, 1.0, 0.0), 0.0)
        rm.offer(blocked_report(0, {1, 2}, 1.0, 0.02), 0.02)
        out = rm.poll(0.2)
        assert len(out) == 1 and out[0].blockers == {1, 2}


# ------------------------------------------------------------- simulator
class TestSimulatorInvariants:
    def test_equal_share_matches_analytic_makespan(self):
        """With static caps the sim must equal the DAG completion-time
        recurrence exactly."""
        from repro.core import equal_share_assignment

        g = listing2_graph()
        specs = homogeneous_cluster(3)
        for P in (2.0, 6.0, 18.6):
            eq = equal_share_assignment(g, specs, P)
            r = simulate(g, specs, P, "equal-share")
            assert r.makespan == pytest.approx(
                g.makespan(eq.time_fn()), rel=1e-9)

    def test_all_jobs_complete_each_policy(self):
        g = is_like(4, "A")
        specs = heterogeneous_cluster(4)
        P = mid_bound(specs)
        for policy in ("equal-share", "heuristic"):
            r = simulate(g, specs, P, policy)
            assert len(r.job_ends) == len(g)

    def test_energy_consistency(self):
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        r = simulate(g, specs, 6.0, "heuristic")
        assert r.energy_j == pytest.approx(r.avg_power_w * r.makespan,
                                           rel=1e-6)
        assert r.peak_power_w >= r.avg_power_w

    def test_equal_share_never_exceeds_bound(self):
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        for P in (3.0, 9.0):
            r = simulate(g, specs, P, "equal-share")
            assert r.peak_power_w <= max(
                P, sum(s.lut.idle_w + DUTY_FLOOR *
                       (s.lut.p_min - s.lut.idle_w) for s in specs)) + 1e-9

    @given(st.integers(0, 10))
    @settings(max_examples=10, deadline=None)
    def test_heuristic_deterministic(self, seed):
        g = listing2_random(3.0, seed=seed)
        specs = homogeneous_cluster(3)
        r1 = simulate(g, specs, 4.0, "heuristic")
        r2 = simulate(g, specs, 4.0, "heuristic")
        assert r1.makespan == r2.makespan


# -------------------------------------------------- paper claims (Figs 8-13)
class TestPaperClaims:
    def test_fig8_speedup_decreases_to_one(self):
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        lut = specs[0].lut
        P_tight = tight_bound(specs)
        P_max = 3 * lut.p_max
        res_t = compare_policies(g, specs, P_tight)
        res_r = compare_policies(g, specs, P_max)
        s_tight = res_t["heuristic"].speedup_vs(res_t["equal-share"])
        s_rel = res_r["heuristic"].speedup_vs(res_r["equal-share"])
        assert s_tight > 1.05
        assert s_rel == pytest.approx(1.0, abs=0.02)
        i_tight = res_t["ilp"].speedup_vs(res_t["equal-share"])
        assert i_tight >= 1.0 - 1e-6

    def test_fig9_speedup_grows_with_stddev(self):
        specs = homogeneous_cluster(3)
        P = tight_bound(specs)
        lo = simulate(listing2_random(0.5, seed=3), specs, P, "heuristic")
        lo_eq = simulate(listing2_random(0.5, seed=3), specs, P,
                         "equal-share")
        hi = simulate(listing2_random(6.0, seed=3), specs, P, "heuristic")
        hi_eq = simulate(listing2_random(6.0, seed=3), specs, P,
                         "equal-share")
        assert (hi_eq.makespan / hi.makespan) > (lo_eq.makespan /
                                                 lo.makespan)

    def test_ep_beats_is_beats_cg(self):
        """Figs. 11-13 ordering: CPU-bound gains most, comm-bound ~none."""
        specs = heterogeneous_cluster(4)
        P = tight_bound(specs, frac=0.3)
        sp = {}
        for name, gen in (("ep", ep_like), ("is", is_like), ("cg", cg_like)):
            g = gen(4, "A")
            heu = simulate(g, specs, P, "heuristic")
            eq = simulate(g, specs, P, "equal-share")
            sp[name] = eq.makespan / heu.makespan
        assert sp["ep"] > sp["is"] > sp["cg"]
        assert sp["cg"] > 0.9  # "minimal negative effect" (paper: 0.98 worst)

    def test_heuristic_avg_power_at_or_above_equal_share(self):
        """§VII-C: heuristic power is almost always slightly higher."""
        g = ep_like(4, "A")
        specs = heterogeneous_cluster(4)
        P = tight_bound(specs, frac=0.3)
        heu = simulate(g, specs, P, "heuristic")
        eq = simulate(g, specs, P, "equal-share")
        assert heu.avg_power_w >= eq.avg_power_w * 0.95

    def test_cg_debounce_suppresses_reports(self):
        g = cg_like(3, "A", iterations=8)
        specs = homogeneous_cluster(3)
        P = mid_bound(specs)
        r = simulate(g, specs, P, "heuristic", latency_s=0.5)
        assert r.suppressed_reports > 0

    def test_pipeline_bubbles_benefit(self):
        """Pipeline warm-up/drain bubbles are blackouts the controller can
        exploit even with perfectly balanced stages (paper §VI uniform)."""
        g = pipeline_graph(stages=4, microbatches=4)
        specs = homogeneous_cluster(4)
        P = tight_bound(specs, frac=0.3)
        heu = simulate(g, specs, P, "heuristic")
        eq = simulate(g, specs, P, "equal-share")
        assert eq.makespan / heu.makespan > 1.1

    def test_moe_hot_expert_benefit(self):
        g = moe_step_graph(4, layers=3, hot_factor=3.0)
        specs = homogeneous_cluster(4)
        P = tight_bound(specs, frac=0.3)
        heu = simulate(g, specs, P, "heuristic")
        eq = simulate(g, specs, P, "equal-share")
        assert eq.makespan / heu.makespan > 1.1
