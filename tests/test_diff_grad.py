"""Finite-difference verification of the differentiable simulator
(ISSUE 9).

Three layers of evidence that ``jax.grad`` through
:mod:`repro.diff.softsim` is trustworthy:

* central finite differences vs ``jax.grad`` on every zoo graph
  (listing2, layered, fork-join, trace-reconstructed), at rel-tol 1e-3
  under x64 (the CI ``diff`` job sets ``JAX_ENABLE_X64=1``; float32
  runs use a correspondingly looser envelope — the FD quotient itself
  loses half the mantissa);
* temperature-annealing convergence: ``|soft - exact|`` must shrink
  monotonically to ~0 against the *exact* numpy simulator running the
  same smooth LUT translation (``BatchSimulator(smooth_lut=True)``);
* parity of the jnp smooth translator with the numpy ``smooth=True``
  path of :func:`repro.core.power.batched_operating_point`.

Gradients are checked at generic cap points (away from LUT state powers
and event ties) — at a tie the true objective is non-differentiable and
the relaxation's gradient is an average over the tie, which is exactly
the caveat docs/differentiable.md documents.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import simulate_batch  # noqa: E402
from repro.core.power import (batched_operating_point,  # noqa: E402
                              homogeneous_cluster, heterogeneous_cluster,
                              lut_table, max_useful_cluster_bound,
                              min_feasible_cluster_bound)
from repro.core.workloads import (fork_join_graph, layered_dag,  # noqa: E402
                                  listing2_graph)
from repro.diff.relax import smooth_operating_point  # noqa: E402
from repro.diff.softsim import (build_soft_arrays,  # noqa: E402
                                soft_makespan, soft_makespan_policy)
from repro.diff.optimize import caps_from_theta  # noqa: E402
from repro.policies import VectorStaticCaps  # noqa: E402
from repro.policies.learned import init_params  # noqa: E402

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 runs without the dev extra
    from _hyp_stub import given, settings, st

X64 = bool(jax.config.jax_enable_x64)
#: FD loses ~half the working precision in the difference quotient;
#: 1e-3 is the acceptance envelope under x64 (the CI diff job), float32
#: runs get the correspondingly scaled envelope.
GRAD_RTOL = 1e-3 if X64 else 5e-2
FD_H = 1e-5 if X64 else 5e-3
T_CHECK = 0.1


def _trace_case():
    from repro.traces import (dumps_trace, loads_trace, record_graph,
                              reconstruct)

    g = listing2_graph()
    specs = homogeneous_cluster(3)
    recon = reconstruct(loads_trace(dumps_trace(record_graph(g, specs))))
    return ("trace-recon", recon.graph, recon.specs)


#: The graph zoo: every shape family the exact backends are tested on.
ZOO = [
    ("listing2", listing2_graph(), homogeneous_cluster(3)),
    ("layered", layered_dag(4, layers=3, seed=11), homogeneous_cluster(4)),
    ("forkjoin", fork_join_graph(4, stages=2, seed=12),
     heterogeneous_cluster(4)),
    _trace_case(),
]
_ids = [z[0] for z in ZOO]


def generic_caps(specs, frac=0.55, seed=5):
    """A cap point away from LUT state powers and symmetry ties."""
    rng = np.random.default_rng(seed)
    tab = lut_table(specs)
    lo, hi = np.asarray(tab.cap_floor), np.asarray(tab.p_max)
    u = rng.uniform(0.35, 0.8, len(specs))
    return lo + (frac * u / u.mean()).clip(0.05, 0.95) * (hi - lo)


def central_fd(f, x, h=FD_H):
    x = np.asarray(x, dtype=float)
    out = np.zeros_like(x)
    for i in range(x.size):
        e = np.zeros_like(x)
        e.flat[i] = h
        out.flat[i] = (float(f(x + e)) - float(f(x - e))) / (2 * h)
    return out


class TestGradMatchesFD:
    @pytest.mark.parametrize("name,graph,specs", ZOO, ids=_ids)
    def test_static_caps_grad(self, name, graph, specs):
        soft = build_soft_arrays(graph, specs)
        caps = generic_caps(specs)
        f = jax.jit(lambda c: soft_makespan(c, soft, T_CHECK))
        grad = np.asarray(jax.grad(f)(jnp.asarray(caps)))
        fd = central_fd(f, caps)
        assert np.linalg.norm(grad - fd) <= \
            GRAD_RTOL * max(np.linalg.norm(fd), 1e-9), \
            f"{name}: grad {grad} vs FD {fd}"

    @pytest.mark.parametrize("name,graph,specs", ZOO[:2], ids=_ids[:2])
    def test_schedule_grad(self, name, graph, specs):
        """(K, N) piecewise-constant schedules differentiate too."""
        soft = build_soft_arrays(graph, specs)
        base = generic_caps(specs)
        sched = np.stack([base, base[::-1].copy()])
        knots = np.array([7.3])
        f = jax.jit(lambda c: soft_makespan(c, soft, T_CHECK,
                                            knot_times=knots))
        grad = np.asarray(jax.grad(f)(jnp.asarray(sched)))
        fd = central_fd(lambda c: f(np.reshape(c, sched.shape)),
                        sched.ravel()).reshape(sched.shape)
        assert np.linalg.norm(grad - fd) <= \
            GRAD_RTOL * max(np.linalg.norm(fd), 1e-9)

    def test_policy_params_grad(self):
        """Gradients w.r.t. the learned-policy MLP parameters, on a
        rho-diverse graph (on rho-uniform graphs every lane's features
        tie and the softmax gradient is legitimately ~0)."""
        graph = layered_dag(4, layers=3, seed=11)
        specs = homogeneous_cluster(4)
        soft = build_soft_arrays(graph, specs)
        params = init_params(seed=3)
        rng = np.random.default_rng(7)
        params["w3"] = rng.normal(0.0, 0.2, params["w3"].shape)
        bound = 0.5 * max_useful_cluster_bound(specs)
        f = jax.jit(lambda w3: soft_makespan_policy(
            {**{k: jnp.asarray(v) for k, v in params.items()},
             "w3": w3}, soft, bound, T_CHECK))
        grad = np.asarray(jax.grad(f)(jnp.asarray(params["w3"])))
        fd = central_fd(f, params["w3"])
        assert np.linalg.norm(fd) > 0          # the signal exists
        assert np.linalg.norm(grad - fd) <= \
            GRAD_RTOL * max(np.linalg.norm(fd), 1e-9)

    @settings(max_examples=5, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_fuzzed_cap_perturbations(self, seed):
        """Hypothesis-driven spot checks: random cap points on the
        layered graph still satisfy the FD envelope (directional
        derivative along a random direction — cheap per example)."""
        graph, specs = ZOO[1][1], ZOO[1][2]
        soft = build_soft_arrays(graph, specs)
        rng = np.random.default_rng(seed)
        caps = generic_caps(specs, frac=float(rng.uniform(0.4, 0.7)),
                            seed=seed)
        d = rng.normal(size=caps.shape)
        d /= np.linalg.norm(d)
        f = jax.jit(lambda c: soft_makespan(c, soft, T_CHECK))
        grad = np.asarray(jax.grad(f)(jnp.asarray(caps)))
        h = FD_H * 10
        fd_dir = (float(f(caps + h * d)) - float(f(caps - h * d))) / (2 * h)
        assert float(grad @ d) == pytest.approx(
            fd_dir, rel=GRAD_RTOL * 10, abs=GRAD_RTOL)


class TestAnnealingConvergence:
    LADDER = (0.5, 0.2, 0.1, 0.05, 0.02)

    @pytest.mark.parametrize("name,graph,specs", ZOO, ids=_ids)
    def test_soft_converges_to_exact(self, name, graph, specs):
        """|soft - exact| -> 0 monotonically down the ladder, where
        "exact" is the numpy simulator under the same smooth LUT
        translation (``smooth_lut=True``) and the same static caps."""
        soft = build_soft_arrays(graph, specs)
        caps = generic_caps(specs)
        bound = float(caps.sum())
        policy = VectorStaticCaps(caps=caps)
        exact = simulate_batch(graph, specs, [bound], policy=policy,
                               smooth_lut=True)[0].makespan
        f = jax.jit(lambda c, t: soft_makespan(c, soft, t))
        errs = [abs(float(f(caps, t)) - exact) for t in self.LADDER]
        noise = 1e-9 if X64 else 1e-5 * max(exact, 1.0)
        for hot, cold in zip(errs, errs[1:]):
            assert cold <= hot + noise, f"{name}: not monotone: {errs}"
        assert errs[-1] <= 1e-3 * exact + (0.0 if X64 else 1e-2), \
            f"{name}: errs {errs} vs exact {exact}"

    def test_scheduled_caps_converge(self):
        graph, specs = ZOO[0][1], ZOO[0][2]
        soft = build_soft_arrays(graph, specs)
        base = generic_caps(specs)
        sched = np.stack([base, base[::-1].copy()])
        knots = [9.7]
        bound = float(base.sum())
        policy = VectorStaticCaps(caps_schedule=sched)
        exact = simulate_batch(
            graph, specs, [bound], policy=policy,
            bound_schedules=[[(knots[0], bound)]],
            smooth_lut=True)[0].makespan
        f = jax.jit(lambda t: soft_makespan(
            jnp.asarray(sched), soft, t, knot_times=np.asarray(knots)))
        errs = [abs(float(f(t)) - exact) for t in self.LADDER]
        noise = 1e-9 if X64 else 1e-5 * max(exact, 1.0)
        for hot, cold in zip(errs, errs[1:]):
            assert cold <= hot + noise, f"not monotone: {errs}"
        assert errs[-1] <= 1e-3 * exact + (0.0 if X64 else 1e-2)


class TestSmoothLutParity:
    def test_jnp_matches_numpy_smooth_path(self):
        """relax.smooth_operating_point must mirror the numpy
        ``smooth=True`` path — including AT state powers, where both
        must also agree with the hard translator."""
        specs = heterogeneous_cluster(4)
        tab = lut_table(specs)
        rng = np.random.default_rng(0)
        pts = [rng.uniform(0.1, 1.2 * float(np.max(tab.p_max)), (16, 4))]
        state_caps = np.where(np.isfinite(tab.state_p), tab.state_p,
                              tab.p_max[:, None])
        pts.append(state_caps.T[:, :4].copy())       # exactly at states
        caps = np.concatenate(pts)
        f_np, d_np, p_np = batched_operating_point(tab, caps, smooth=True)
        f_j, d_j, p_j = (np.asarray(a, dtype=float) for a in
                         smooth_operating_point(tab, jnp.asarray(caps)))
        tol = 1e-9 if X64 else 1e-4
        np.testing.assert_allclose(f_j, f_np, rtol=tol, atol=tol)
        np.testing.assert_allclose(d_j, d_np, rtol=tol, atol=tol)
        np.testing.assert_allclose(p_j, p_np, rtol=tol, atol=tol)

    def test_agrees_with_hard_translator_at_states(self):
        specs = homogeneous_cluster(2)
        tab = lut_table(specs)
        caps = np.asarray(tab.state_p)[0][None, :].repeat(2, 0).T
        hard = batched_operating_point(tab, caps)
        smooth = batched_operating_point(tab, caps, smooth=True)
        for h, s in zip(hard, smooth):
            np.testing.assert_allclose(s, h, rtol=1e-12)


class TestTransformCompat:
    def test_vmap_matches_loop(self):
        graph, specs = ZOO[0][1], ZOO[0][2]
        soft = build_soft_arrays(graph, specs)
        rng = np.random.default_rng(2)
        caps_b = np.stack([generic_caps(specs, seed=s) for s in range(4)])
        f = jax.jit(lambda c: soft_makespan(c, soft, T_CHECK))
        batched = np.asarray(jax.vmap(f)(jnp.asarray(caps_b)))
        single = np.array([float(f(c)) for c in caps_b])
        np.testing.assert_allclose(batched, single,
                                   rtol=1e-6 if X64 else 1e-5)

    def test_simplex_parameterization_respects_bound(self):
        """caps_from_theta outputs sum exactly to the bound and sit at
        or above the duty floor for any theta."""
        specs = heterogeneous_cluster(3)
        tab = lut_table(specs)
        floor = jnp.asarray(tab.cap_floor)
        bound = 11.0
        rng = np.random.default_rng(3)
        for _ in range(5):
            theta = jnp.asarray(rng.normal(0, 3, 3))
            caps = caps_from_theta(theta, floor, bound)
            assert float(caps.sum()) == pytest.approx(bound, rel=1e-6)
            assert bool((caps >= floor - 1e-9).all())
