"""Docs stay runnable and linked (ISSUE 4 satellites).

Three layers of enforcement:

* every ``>>>`` example in the sweep/batchsim/scenarios module
  docstrings runs under ``doctest`` (the docs quote these modules);
* every ``>>>`` example in ``docs/*.md`` runs under ``doctest`` too, so
  the authoring guides cannot rot;
* a pydocstyle-lite audit: public classes/functions/methods of the
  sweep and batchsim modules must carry docstrings;
* relative markdown links in README.md and docs/ must resolve.
"""

import doctest
import inspect
import pathlib
import re

import pytest

import repro.cluster.arrivals
import repro.cluster.metrics
import repro.cluster.policies
import repro.cluster.scheduler
import repro.core.batchsim
import repro.core.scenarios
import repro.core.sweep
import repro.obs.metrics
import repro.obs.regress
import repro.obs.timeline
import repro.obs.trace
import repro.policies.learned

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOCS = ROOT / "docs"
FLAGS = doctest.ELLIPSIS | doctest.NORMALIZE_WHITESPACE

DOCTEST_MODULES = [repro.core.sweep, repro.core.batchsim,
                   repro.core.scenarios, repro.cluster.arrivals,
                   repro.cluster.policies, repro.cluster.scheduler,
                   repro.cluster.metrics, repro.policies.learned,
                   repro.obs.trace, repro.obs.metrics,
                   repro.obs.timeline, repro.obs.regress]


@pytest.mark.parametrize("mod", DOCTEST_MODULES,
                         ids=lambda m: m.__name__)
def test_module_doctests(mod):
    result = doctest.testmod(mod, optionflags=FLAGS, verbose=False)
    assert result.attempted > 0, f"{mod.__name__} lost its examples"
    assert result.failed == 0


def _doc_pages():
    assert DOCS.is_dir(), "docs/ tree is missing"
    pages = sorted(DOCS.glob("*.md"))
    assert {p.name for p in pages} >= {"architecture.md", "scenarios.md",
                                       "backends.md"}
    return pages


@pytest.mark.parametrize("page", _doc_pages(), ids=lambda p: p.name)
def test_docs_examples_run(page):
    result = doctest.testfile(str(page), module_relative=False,
                              optionflags=FLAGS, verbose=False)
    assert result.failed == 0


@pytest.mark.parametrize(
    "page", [ROOT / "README.md"] + _doc_pages(), ids=lambda p: p.name)
def test_relative_links_resolve(page):
    for target in re.findall(r"\[[^\]]*\]\(([^)\s#]+)(?:#[^)]*)?\)",
                             page.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        assert (page.parent / target).exists(), \
            f"{page.name}: broken relative link {target!r}"


def _public_members(mod):
    for name, obj in vars(mod).items():
        if name.startswith("_"):
            continue
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue
        if getattr(obj, "__module__", None) != mod.__name__:
            continue   # re-exports are documented at their home
        yield f"{mod.__name__}.{name}", obj
        if inspect.isclass(obj):
            for mname, member in vars(obj).items():
                if mname.startswith("_"):
                    continue
                if isinstance(member, property):
                    yield f"{mod.__name__}.{name}.{mname}", member.fget
                elif inspect.isfunction(member):
                    yield f"{mod.__name__}.{name}.{mname}", member
                elif isinstance(member, (classmethod, staticmethod)):
                    yield (f"{mod.__name__}.{name}.{mname}",
                           member.__func__)


@pytest.mark.parametrize("mod", [repro.core.sweep, repro.core.batchsim,
                                 repro.core.scenarios,
                                 repro.cluster.arrivals,
                                 repro.cluster.policies,
                                 repro.cluster.scheduler,
                                 repro.cluster.metrics,
                                 repro.obs.trace, repro.obs.metrics,
                                 repro.obs.timeline,
                                 repro.obs.regress],
                         ids=lambda m: m.__name__)
def test_public_api_has_docstrings(mod):
    """pydocstyle-lite: the bucket planner / mask conventions must stay
    documented at the definition site."""
    missing = [path for path, obj in _public_members(mod)
               if not inspect.getdoc(obj)]
    assert not missing, f"undocumented public APIs: {missing}"
