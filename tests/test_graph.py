"""Graph layer tests — validated against the paper's own numbers:

* Fig. 4 walk-through: nominal total execution time = 19 time units,
  J_{*,2} all start at 3, the critical path starts at J_{2,1}, and the
  last jobs to finish are J_{2,5} and J_{3,5};
* Table I max-depths; Table II depth ranges.
"""

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property-based tests skip without hypothesis
    from _hyp_stub import given, settings, st

from repro.core import (Job, JobDependencyGraph, listing2_graph,
                        listing2_random, listing2_uniform)
from repro.core.graph import GraphError

NOMINAL = lambda job: job.work  # noqa: E731  (work == nominal time)


@pytest.fixture(scope="module")
def g():
    return listing2_graph()


# ------------------------------------------------------------- paper Fig. 4
class TestListing2:
    def test_fifteen_jobs_three_nodes(self, g):
        assert len(g) == 15
        assert g.nodes == [1, 2, 3]

    def test_total_execution_time_is_19(self, g):
        assert g.makespan(NOMINAL) == pytest.approx(19.0)

    def test_j2_starts_at_3(self, g):
        start, _ = g.completion_times(NOMINAL)
        for i in (1, 2, 3):
            assert start[(i, 2)] == pytest.approx(3.0)

    def test_critical_path_starts_at_J21(self, g):
        path = g.critical_path(NOMINAL)
        assert path[0] == (2, 1)

    def test_last_jobs_are_J25_J35(self, g):
        _, comp = g.completion_times(NOMINAL)
        finishers = sorted(j for j, c in comp.items()
                           if c == pytest.approx(19.0))
        assert finishers == [(2, 5), (3, 5)]

    def test_table_I_max_depths(self, g):
        depth = g.max_depths()
        expected = {  # paper Table I
            (1, 1): 0, (2, 1): 0, (3, 1): 0,
            (1, 2): 1, (2, 2): 1, (3, 2): 1,
            (1, 3): 4, (2, 3): 2, (3, 3): 3,
            (1, 4): 5, (2, 4): 3, (3, 4): 4,
            (1, 5): 6, (2, 5): 6, (3, 5): 6,
        }
        assert depth == expected

    def test_table_II_depth_ranges(self, g):
        ranges = g.depth_ranges()
        expected = {  # paper Table II
            (1, 1): (0, 0), (2, 1): (0, 0), (3, 1): (0, 0),
            (1, 2): (1, 1), (2, 2): (1, 1), (3, 2): (1, 2),
            (1, 3): (4, 4), (2, 3): (2, 2), (3, 3): (3, 3),
            (1, 4): (5, 5), (2, 4): (3, 5), (3, 4): (4, 5),
            (1, 5): (6, 6), (2, 5): (6, 6), (3, 5): (6, 6),
        }
        assert ranges == expected

    def test_makespan_equals_longest_path_sum(self, g):
        """Definition 3: E_D = max over execution paths of the time sum."""
        best = max(sum(g[j].work for j in path)
                   for path in g.execution_paths())
        assert best == pytest.approx(g.makespan(NOMINAL))

    def test_roundtrip_text(self, g):
        g2 = JobDependencyGraph.from_text(g.to_text())
        assert set(g2.jobs) == set(g.jobs)
        assert g2.makespan(NOMINAL) == pytest.approx(19.0)
        for jid in g.jobs:
            assert set(g2[jid].deps) == set(g[jid].deps)


# ---------------------------------------------------------------- structure
class TestStructure:
    def test_initial_and_final_jobs(self, g):
        assert sorted(g.initial_jobs()) == [(1, 1), (2, 1), (3, 1)]
        assert sorted(g.final_jobs()) == [(1, 5), (2, 5), (3, 5)]

    def test_cycle_detection(self):
        g = JobDependencyGraph()
        g.add(0, 0, 1.0, deps=[(0, 1)])
        g.add(0, 1, 1.0, deps=[(0, 0)])
        with pytest.raises(GraphError):
            g.topological_order()

    def test_missing_dep_detection(self):
        g = JobDependencyGraph()
        g.add(0, 0, 1.0, deps=[(5, 5)])
        with pytest.raises(GraphError):
            g.topological_order()

    def test_duplicate_job_rejected(self):
        g = JobDependencyGraph()
        g.add(0, 0, 1.0)
        with pytest.raises(GraphError):
            g.add(0, 0, 2.0)

    def test_validate_multi_dep_same_node(self):
        g = JobDependencyGraph()
        g.add(1, 0, 1.0)
        g.add(1, 1, 1.0, deps=[(1, 0)])
        g.add(0, 0, 1.0)
        g.add(0, 1, 1.0, deps=[(0, 0), (1, 0), (1, 1)])
        with pytest.raises(GraphError, match="multiple jobs"):
            g.validate()

    def test_depth_level_sets_cover_every_job(self, g):
        levels = g.depth_level_sets()
        seen = {j for js in levels.values() for j in js}
        assert seen == set(g.jobs)
        # stretched job J_{3,2} appears at both levels 1 and 2 (§IV-A)
        assert (3, 2) in levels[1] and (3, 2) in levels[2]


# ------------------------------------------------------------ property tests
@st.composite
def random_dag(draw):
    """Layered random DAGs shaped like synchronised parallel programs."""
    n_nodes = draw(st.integers(2, 5))
    n_jobs = draw(st.integers(1, 6))
    g = JobDependencyGraph()
    for node in range(n_nodes):
        for j in range(n_jobs):
            deps = [(node, j - 1)] if j > 0 else []
            if j > 0 and draw(st.booleans()):
                other = draw(st.integers(0, n_nodes - 1))
                if other != node:
                    deps.append((other, j - 1))
            work = draw(st.floats(0.1, 50.0, allow_nan=False))
            g.add(node, j, work, deps=deps)
    return g


@given(random_dag())
@settings(max_examples=60, deadline=None)
def test_depth_range_invariants(g):
    """Delta(J) always starts at delta(J); children start strictly deeper;
    every parent's range ends before every child's max-depth."""
    depth = g.max_depths()
    ranges = g.depth_ranges()
    ch = g.children()
    for jid, (lo, hi) in ranges.items():
        assert lo == depth[jid]
        assert hi >= lo - 1
        for kid in ch[jid]:
            assert depth[kid] > hi  # stretching never crosses a child

    # makespan equals max completion, independent of enumeration
    mk = g.makespan(NOMINAL)
    _, comp = g.completion_times(NOMINAL)
    assert mk == pytest.approx(max(comp.values()))


@given(random_dag(), st.floats(1.1, 4.0))
@settings(max_examples=30, deadline=None)
def test_makespan_monotone_in_work(g, factor):
    """Scaling all work scales the makespan linearly (no hidden state)."""
    assert g.scaled(factor).makespan(NOMINAL) == \
        pytest.approx(factor * g.makespan(NOMINAL))


@given(st.floats(0.0, 6.0), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_listing2_random_valid(stddev, seed):
    g = listing2_random(stddev, seed=seed)
    assert len(g) == 15
    assert g.makespan(NOMINAL) > 0


def test_listing2_uniform_structure():
    g = listing2_uniform(10.0)
    assert g.makespan(NOMINAL) > 0
    assert g.max_depths() == listing2_graph().max_depths()
