"""Differential oracle for the batch backends (ISSUEs 2 & 3).

The batch simulators are only trustworthy against the discrete-event
simulator's answers.  For every *exact* registered vector policy
(``equal-share``, ``ilp``, ``ilp-makespan``, ``oracle`` — cap decisions
that depend only on state transitions, which the batch backends resolve
at exact event times) the backends must agree on makespan within
``2 * dt`` and on energy within 1% across the Listing-2 family, a
hand-rolled TraceBuilder graph, and the NPB-analogue generators.  When
jax is installed the comparison is *three-way*: event vs numpy-vector
vs the compiled :mod:`repro.backends.jax` engine, held to the same
envelopes.  The tick-quantized ``heuristic`` (``exact=False``) is held
to a looser envelope.  The SweepEngine ``executor="vector"`` and
``executor="jax"`` paths are checked against the thread path on whole
grids, including fallback of non-batchable policies — which must now be
*visible* via ``SweepRecord.backend`` / ``fallback_reason``.
"""

import pytest

from repro.core import (Scenario, SweepEngine, TraceBuilder, cg_like,
                        ep_like, heterogeneous_cluster, homogeneous_cluster,
                        is_like, listing2_graph, listing2_random,
                        listing2_uniform, scenario_grid, simulate,
                        simulate_batch)
from repro.backends.jax import HAS_JAX
from repro.policies import get_vector_policy, vector_policies

if HAS_JAX:
    from repro.backends.jax import simulate_batch_jax

DT = 0.05
MAKESPAN_ATOL = 2 * DT
ENERGY_RTOL = 0.01

#: Every registered vector policy, deduplicated across aliases (the
#: canonical ``.name`` is always itself a registry key) and split by its
#: declared differential contract.
EXACT = sorted({p.name for p in map(get_vector_policy, vector_policies())
                if p.exact})
APPROX = sorted({p.name for p in map(get_vector_policy, vector_policies())
                 if not p.exact})


def ring_trace_graph():
    """A small TraceBuilder workload: compute, ring send/recv, allreduce."""
    tb = TraceBuilder(3)
    for node, w in ((0, 5.0), (1, 9.0), (2, 3.0)):
        tb.compute(node, w, cpu_frac=0.8)
    for node in range(3):
        tb.send(node, (node + 1) % 3)
    for node in range(3):
        tb.recv(node, (node - 1) % 3)
    for node, w in ((0, 4.0), (1, 2.0), (2, 6.0)):
        tb.compute(node, w)
    tb.collective("allreduce", [0, 1, 2])
    return tb.build()


#: (id, graph, specs, bounds) — the Listing-2 family is cheap enough for
#: the self-solving ILP policies; the generated graphs run solver-free
#: policies only (an ILP per (graph, bound) would dominate the suite).
LISTING2_CASES = [
    ("l2", listing2_graph(), homogeneous_cluster(3), (2.5, 6.0, 12.0)),
    ("l2-uniform", listing2_uniform(10.0), homogeneous_cluster(3),
     (3.0, 9.0)),
    ("l2-random", listing2_random(4.0, seed=3), homogeneous_cluster(3),
     (4.0, 8.0)),
]
def _trace_ingested(name, graph, specs, bounds, **record_kw):
    """A case whose graph went through the full trace pipeline: record
    -> serialise -> parse -> calibrate -> reconstruct (ISSUE 5
    differential coverage — ingested graphs must obey the same
    event/vector/jax envelopes as native ones)."""
    from repro.traces import (dumps_trace, loads_trace, record_graph,
                              reconstruct)

    trace = loads_trace(dumps_trace(record_graph(graph, specs,
                                                 **record_kw)))
    recon = reconstruct(trace)
    return (name, recon.graph, recon.specs, bounds)


GENERATED_CASES = [
    ("ring-trace", ring_trace_graph(), homogeneous_cluster(3), (4.0, 8.0)),
    ("ep-het4", ep_like(4, "A"), heterogeneous_cluster(4), (6.0, 12.0)),
    ("cg-homo3", cg_like(3, "A"), homogeneous_cluster(3), (5.0, 9.0)),
    ("is-het3", is_like(3, "A"), heterogeneous_cluster(3), (6.0, 15.0)),
    _trace_ingested("ingested-l2", listing2_graph(),
                    homogeneous_cluster(3), (2.5, 9.0)),
    _trace_ingested("ingested-ep4", ep_like(4, "A"),
                    heterogeneous_cluster(4), (6.0, 12.0),
                    freqs="random", seed=13),
]
_ids = [c[0] for c in LISTING2_CASES + GENERATED_CASES]


def assert_backends_agree(graph, specs, bounds, policy):
    """Event vs vector — and, when jax is installed, vs the compiled
    engine — under the same differential envelopes."""
    batch = {"vec": simulate_batch(graph, specs, bounds, policy, dt=DT)}
    if HAS_JAX:
        batch["jax"] = simulate_batch_jax(graph, specs, bounds, policy,
                                          dt=DT)
    for i, bound in enumerate(bounds):
        ev = simulate(graph, specs, bound, policy)
        for label, results in batch.items():
            got = results[i]
            assert got.makespan == pytest.approx(ev.makespan,
                                                 abs=MAKESPAN_ATOL), \
                (f"{policy} @ {bound}W: event {ev.makespan} vs "
                 f"{label} {got.makespan}")
            assert got.energy_j == pytest.approx(ev.energy_j,
                                                 rel=ENERGY_RTOL)
            assert got.over_budget_time == pytest.approx(
                ev.over_budget_time, abs=2 * DT)
            assert got.job_ends.keys() == ev.job_ends.keys()


class TestExactPolicies:
    def test_registry_exposes_exact_policies(self):
        assert "equal-share" in EXACT and "ilp" in EXACT \
            and "oracle" in EXACT
        assert APPROX == ["heuristic", "learned"]

    @pytest.mark.parametrize("policy", EXACT)
    @pytest.mark.parametrize(
        "case", LISTING2_CASES, ids=[c[0] for c in LISTING2_CASES])
    def test_listing2_family(self, case, policy):
        _, graph, specs, bounds = case
        assert_backends_agree(graph, specs, bounds, policy)

    @pytest.mark.parametrize("policy",
                             [p for p in EXACT if not p.startswith("ilp")])
    @pytest.mark.parametrize(
        "case", GENERATED_CASES, ids=[c[0] for c in GENERATED_CASES])
    def test_generated_graphs(self, case, policy):
        _, graph, specs, bounds = case
        assert_backends_agree(graph, specs, bounds, policy)

    def test_exactness_is_tight_not_just_within_tolerance(self):
        """The wave scheme resolves completions at exact event times, so
        static-cap policies should agree to float noise, not merely 2dt."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        for bound in (2.5, 12.0):
            ev = simulate(g, specs, bound, "equal-share")
            vec = simulate_batch(g, specs, [bound], "equal-share")[0]
            assert vec.makespan == pytest.approx(ev.makespan, rel=1e-9)
            assert vec.energy_j == pytest.approx(ev.energy_j, rel=1e-9)


class TestApproxHeuristic:
    @pytest.mark.parametrize("bound", [2.5, 6.0, 12.0])
    def test_tracks_event_heuristic(self, bound):
        """Tick-quantized control plane: within 10% of the event
        heuristic's makespan and never worse than equal-share."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        ev = simulate(g, specs, bound, "heuristic")
        eq = simulate(g, specs, bound, "equal-share")
        vec = simulate_batch(g, specs, [bound], "heuristic", dt=DT)[0]
        assert vec.makespan == pytest.approx(ev.makespan, rel=0.10)
        assert vec.makespan <= eq.makespan * 1.01


class TestSweepVectorExecutor:
    def grid(self):
        specs = homogeneous_cluster(3)
        graphs = {"l2": listing2_graph(),
                  "l2r": listing2_random(3.0, seed=7)}
        return scenario_grid(graphs, specs, [4.0, 9.0],
                             ("equal-share", "ilp", "oracle"))

    def test_matches_thread_executor(self):
        scenarios = self.grid()
        ev = SweepEngine(executor="thread").run(scenarios)
        vec = SweepEngine(executor="vector").run(scenarios)
        assert not ev.failures and not vec.failures
        for a, b in zip(ev.records, vec.records):
            assert b.result.makespan == pytest.approx(a.result.makespan,
                                                      abs=MAKESPAN_ATOL)
            assert b.result.energy_j == pytest.approx(a.result.energy_j,
                                                      rel=ENERGY_RTOL)

    def test_non_vectorizable_policies_fall_back(self):
        """countdown has no vector implementation and an explicit policy
        instance bypasses the registry: both run through the event
        simulator and agree with a plain simulate() call."""
        from repro.policies import OnlineHeuristicPolicy

        g = listing2_graph()
        specs = homogeneous_cluster(3)
        scenarios = scenario_grid(
            {"l2": g}, specs, [4.0],
            ("equal-share", "countdown", OnlineHeuristicPolicy()))
        sweep = SweepEngine(executor="vector").run(scenarios)
        assert not sweep.failures
        for policy in ("countdown", "heuristic"):
            ref = simulate(g, specs, 4.0, policy)
            assert sweep.result("l2", policy, 4.0).makespan == \
                pytest.approx(ref.makespan, rel=1e-12)

    def test_fallbacks_are_recorded_not_silent(self):
        """Every record carries the backend that actually ran it, and
        fallbacks off the requested batched backend carry a reason."""
        from repro.policies import OnlineHeuristicPolicy

        g = listing2_graph()
        specs = homogeneous_cluster(3)
        scenarios = scenario_grid(
            {"l2": g}, specs, [4.0],
            ("equal-share", "countdown", OnlineHeuristicPolicy()))
        sweep = SweepEngine(executor="vector").run(scenarios)
        by_policy = {r.scenario.policy_key: r for r in sweep.records}
        assert by_policy["equal-share"].backend == "vector"
        assert by_policy["equal-share"].fallback_reason is None
        assert by_policy["countdown"].backend == "event"
        assert by_policy["countdown"].fallback_reason == \
            "no-vector-policy(countdown)"
        assert by_policy["heuristic"].backend == "event"
        assert by_policy["heuristic"].fallback_reason == "policy-instance"
        summary = sweep.backend_summary()
        assert "event=2" in summary and "vector=1" in summary
        assert "no-vector-policy(countdown)" in summary
        rows = sweep.rows()
        assert all("backend" in row for row in rows)

    def test_bound_schedule_runs_batched(self):
        """Dynamic cluster bounds are no longer a fallback class: the
        scheduled arrival resolves inside the vector batch at its exact
        time and the answer still matches the event simulator."""
        g = listing2_graph()
        specs = tuple(homogeneous_cluster(3))
        s = Scenario(name="sched", graph=g, specs=specs, bound_w=9.0,
                     policy="equal-share", bound_schedule=((10.0, 3.0),))
        sweep = SweepEngine(executor="vector").run([s])
        assert not sweep.failures
        rec = sweep.records[0]
        assert rec.backend == "vector"
        assert rec.fallback_reason is None
        ref = simulate(g, specs, 9.0, "equal-share",
                       bound_schedule=[(10.0, 3.0)])
        assert sweep.result("sched", "equal-share", 9.0).makespan == \
            pytest.approx(ref.makespan, rel=1e-12)

    def test_batch_failure_is_per_scenario(self):
        """An infeasible ILP bound fails its own cell, not the batch."""
        g = listing2_graph()
        specs = tuple(homogeneous_cluster(3))
        scenarios = [
            Scenario(name="ok", graph=g, specs=specs, bound_w=6.0,
                     policy="ilp"),
            Scenario(name="bad", graph=g, specs=specs, bound_w=0.1,
                     policy="ilp"),
        ]
        sweep = SweepEngine(executor="vector").run(scenarios)
        assert len(sweep.failures) == 1
        assert sweep.failures[0].scenario.name == "bad"
        assert sweep.result("ok", "ilp", 6.0).makespan > 0


@pytest.mark.skipif(not HAS_JAX, reason="jax not installed")
class TestSweepJaxExecutor:
    def test_matches_thread_executor(self):
        specs = homogeneous_cluster(3)
        graphs = {"l2": listing2_graph(),
                  "l2r": listing2_random(3.0, seed=7)}
        scenarios = scenario_grid(graphs, specs, [4.0, 9.0],
                                  ("equal-share", "ilp", "oracle"))
        ev = SweepEngine(executor="thread").run(scenarios)
        jx = SweepEngine(executor="jax").run(scenarios)
        assert not ev.failures and not jx.failures
        assert all(r.backend == "jax" for r in jx.records)
        for a, b in zip(ev.records, jx.records):
            assert b.result.makespan == pytest.approx(a.result.makespan,
                                                      abs=MAKESPAN_ATOL)
            assert b.result.energy_j == pytest.approx(a.result.energy_j,
                                                      rel=ENERGY_RTOL)

    def test_falls_back_through_vector_to_event(self):
        """countdown has neither a jax nor a vector implementation ->
        event; a traced scenario is vector-eligible but not
        jax-eligible -> vector, reason recorded."""
        g = listing2_graph()
        specs = tuple(homogeneous_cluster(3))
        scenarios = [
            Scenario(name="plain", graph=g, specs=specs, bound_w=6.0,
                     policy="equal-share"),
            Scenario(name="traced", graph=g, specs=specs, bound_w=6.0,
                     policy="equal-share", trace_every=0.0),
            Scenario(name="cd", graph=g, specs=specs, bound_w=6.0,
                     policy="countdown"),
        ]
        sweep = SweepEngine(executor="jax").run(scenarios)
        assert not sweep.failures
        by_name = {r.scenario.name: r for r in sweep.records}
        assert by_name["plain"].backend == "jax"
        assert by_name["plain"].fallback_reason is None
        assert by_name["traced"].backend == "vector"
        assert by_name["traced"].fallback_reason == "trace-retention"
        assert by_name["traced"].result.power_trace  # trace retained
        assert by_name["cd"].backend == "event"
        assert by_name["cd"].fallback_reason == \
            "no-vector-policy(countdown)"
        ref = simulate(g, specs, 6.0, "countdown")
        assert by_name["cd"].result.makespan == \
            pytest.approx(ref.makespan, rel=1e-12)

    def test_batch_failure_is_per_scenario(self):
        g = listing2_graph()
        specs = tuple(homogeneous_cluster(3))
        scenarios = [
            Scenario(name="ok", graph=g, specs=specs, bound_w=6.0,
                     policy="ilp"),
            Scenario(name="bad", graph=g, specs=specs, bound_w=0.1,
                     policy="ilp"),
        ]
        sweep = SweepEngine(executor="jax").run(scenarios)
        assert len(sweep.failures) == 1
        assert sweep.failures[0].scenario.name == "bad"
        assert sweep.result("ok", "ilp", 6.0).makespan > 0


#: Per-row dynamic-bound schedules: a mid-run drop, and a drop that
#: later recovers (the EcoShift-style "cap comes back" case).
SCHEDULES = [
    pytest.param(((10.0, 4.0),), id="drop"),
    pytest.param(((6.0, 5.0), (15.0, 12.0)), id="drop-recover"),
]


class TestBoundSchedules:
    """Dynamic cluster bounds in all three backends (ISSUE 4): the
    batched backends resolve scheduled arrivals at exact event times,
    so exact policies stay inside the differential envelopes."""

    @pytest.mark.parametrize("schedule", SCHEDULES)
    @pytest.mark.parametrize("policy", ["equal-share", "ilp", "oracle"])
    def test_vector_matches_event(self, policy, schedule):
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        for bound in (6.0, 9.0):
            ev = simulate(g, specs, bound, policy,
                          bound_schedule=schedule)
            vec = simulate_batch(g, specs, [bound], policy, dt=DT,
                                 bound_schedules=[schedule])[0]
            assert vec.makespan == pytest.approx(ev.makespan,
                                                 abs=MAKESPAN_ATOL)
            assert vec.energy_j == pytest.approx(ev.energy_j,
                                                 rel=ENERGY_RTOL)
            assert vec.over_budget_time == pytest.approx(
                ev.over_budget_time, abs=2 * DT)

    @pytest.mark.skipif(not HAS_JAX, reason="jax not installed")
    @pytest.mark.parametrize("schedule", SCHEDULES)
    @pytest.mark.parametrize("policy", ["equal-share", "ilp", "oracle"])
    def test_jax_matches_event(self, policy, schedule):
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        for bound in (6.0, 9.0):
            ev = simulate(g, specs, bound, policy,
                          bound_schedule=schedule)
            jx = simulate_batch_jax(g, specs, [bound], policy, dt=DT,
                                    bound_schedules=[schedule])[0]
            assert jx.makespan == pytest.approx(ev.makespan,
                                                abs=MAKESPAN_ATOL)
            assert jx.energy_j == pytest.approx(ev.energy_j,
                                                rel=ENERGY_RTOL)

    def test_schedule_is_tight_for_static_caps(self):
        """Equal-share caps change only at bound arrivals, which the
        wave scheme lands on exactly — agreement to float noise."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        schedule = ((5.0, 3.5), (12.0, 9.0))
        ev = simulate(g, specs, 7.0, "equal-share",
                      bound_schedule=schedule)
        vec = simulate_batch(g, specs, [7.0], "equal-share",
                             bound_schedules=[schedule])[0]
        assert vec.makespan == pytest.approx(ev.makespan, rel=1e-9)
        assert vec.energy_j == pytest.approx(ev.energy_j, rel=1e-9)

    def test_same_time_arrivals_apply_in_given_order(self):
        """Two arrivals at the same instant resolve last-writer-wins in
        the order given — the event heap's semantics (the sort that
        orders the schedule must be stable)."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        schedule = ((10.0, 12.0), (10.0, 4.0))   # 4.0 W must win
        ev = simulate(g, specs, 9.0, "equal-share",
                      bound_schedule=schedule)
        vec = simulate_batch(g, specs, [9.0], "equal-share",
                             bound_schedules=[schedule])[0]
        assert vec.makespan == pytest.approx(ev.makespan, rel=1e-9)
        assert vec.energy_j == pytest.approx(ev.energy_j, rel=1e-9)

    def test_negative_schedule_time_rejected(self):
        """A past arrival would run a wave backwards and corrupt the
        energy integral — rejected up front."""
        with pytest.raises(ValueError, match="must be >= 0"):
            simulate_batch(listing2_graph(), homogeneous_cluster(3),
                           [9.0], "equal-share",
                           bound_schedules=[((-5.0, 3.0),)])

    def test_heuristic_with_schedule_tracks_event(self):
        """The tick-quantized heuristic sees a bound change one ring-
        buffer delay late — held to its usual loose envelope."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        schedule = ((8.0, 4.0),)
        ev = simulate(g, specs, 9.0, "heuristic",
                      bound_schedule=schedule)
        vec = simulate_batch(g, specs, [9.0], "heuristic", dt=DT,
                             bound_schedules=[schedule])[0]
        assert vec.makespan == pytest.approx(ev.makespan, rel=0.10)


def mixed_rows():
    """Three distinct (N, J) shapes on two different cluster families."""
    return [
        ("l2", listing2_graph(), homogeneous_cluster(3), 6.0),
        ("ring", ring_trace_graph(), homogeneous_cluster(3), 8.0),
        ("ep4", ep_like(4, "A"), heterogeneous_cluster(4), 12.0),
        ("cg3", cg_like(3, "A"), homogeneous_cluster(3), 7.0),
    ]


class TestPaddedBatches:
    """Mixed-shape padded buckets (the ISSUE 4 tentpole): one batch,
    heterogeneous rows, each row matching its own event-simulator run."""

    @pytest.mark.parametrize("policy",
                             [p for p in EXACT if not p.startswith("ilp")])
    def test_padded_vector_matches_event(self, policy):
        from repro.core.batchsim import BatchSimulator

        rows = mixed_rows()
        sim = BatchSimulator.padded(
            [(g, specs) for _, g, specs, _ in rows],
            [b for _, _, _, b in rows], policy=policy, dt=DT)
        results = sim.run()
        for (name, g, specs, bound), got in zip(rows, results):
            ev = simulate(g, specs, bound, policy)
            assert got.makespan == pytest.approx(
                ev.makespan, abs=MAKESPAN_ATOL), f"{name}/{policy}"
            assert got.energy_j == pytest.approx(ev.energy_j,
                                                 rel=ENERGY_RTOL)
            assert got.job_ends.keys() == ev.job_ends.keys()

    @pytest.mark.skipif(not HAS_JAX, reason="jax not installed")
    @pytest.mark.parametrize("policy",
                             [p for p in EXACT if not p.startswith("ilp")])
    def test_padded_jax_matches_event(self, policy):
        from repro.backends.jax import JaxBatchSimulator

        rows = mixed_rows()
        sim = JaxBatchSimulator.padded(
            [(g, specs) for _, g, specs, _ in rows],
            [b for _, _, _, b in rows], policy=policy, dt=DT)
        results = sim.run()
        for (name, g, specs, bound), got in zip(rows, results):
            ev = simulate(g, specs, bound, policy)
            assert got.makespan == pytest.approx(
                ev.makespan, abs=MAKESPAN_ATOL), f"{name}/{policy}"
            assert got.energy_j == pytest.approx(ev.energy_j,
                                                 rel=ENERGY_RTOL)

    def test_padded_ilp_uses_per_row_graphs(self):
        """The ILP policy must solve each row's OWN graph — a padded
        batch of two different graphs gets two different assignments."""
        from repro.core.batchsim import BatchSimulator

        g1, g2 = listing2_graph(), listing2_random(4.0, seed=9)
        specs = homogeneous_cluster(3)
        sim = BatchSimulator.padded([(g1, specs), (g2, specs)],
                                    [6.0, 6.0], policy="ilp")
        results = sim.run()
        for g, got in zip((g1, g2), results):
            ev = simulate(g, specs, 6.0, "ilp")
            assert got.makespan == pytest.approx(ev.makespan,
                                                 abs=MAKESPAN_ATOL)

    def test_sweep_vector_buckets_mixed_shapes(self):
        """A mixed-shape grid batches onto the vector backend with zero
        event fallbacks and visible bucket accounting."""
        scenarios = [
            Scenario(name=name, graph=g, specs=tuple(specs),
                     bound_w=bound, policy=p)
            for name, g, specs, bound in mixed_rows()
            for p in ("equal-share", "oracle")
        ]
        sweep = SweepEngine(executor="vector").run(scenarios)
        assert not sweep.failures
        assert all(r.backend == "vector" for r in sweep.records)
        assert all(r.bucket for r in sweep.records)
        assert "batches: vector=" in sweep.backend_summary()
        for rec in sweep.records:
            s = rec.scenario
            ev = simulate(s.graph, s.specs, s.bound_w, s.policy)
            assert rec.result.makespan == pytest.approx(
                ev.makespan, abs=MAKESPAN_ATOL)

    def test_backend_summary_counts_scenarios_not_buckets(self):
        """Fallback accounting stays truthful under bucketing: a padded
        bucket of N scenarios reports N per-scenario records."""
        scenarios = [
            Scenario(name=name, graph=g, specs=tuple(specs),
                     bound_w=bound, policy="equal-share")
            for name, g, specs, bound in mixed_rows()
        ]
        sweep = SweepEngine(executor="vector").run(scenarios)
        assert len(sweep.records) == len(scenarios)
        summary = sweep.backend_summary()
        assert f"vector={len(scenarios)}" in summary
        n_buckets = len({r.bucket for r in sweep.records})
        assert f"batches: vector={n_buckets}" in summary
        assert n_buckets < len(scenarios)


class TestBatchSimValidation:
    def test_rejects_bad_dt(self):
        with pytest.raises(ValueError, match="dt"):
            simulate_batch(listing2_graph(), homogeneous_cluster(3), [6.0],
                           dt=0.0)

    def test_rejects_empty_bounds(self):
        with pytest.raises(ValueError, match="bounds"):
            simulate_batch(listing2_graph(), homogeneous_cluster(3), [])

    def test_rejects_spec_mismatch(self):
        with pytest.raises(ValueError, match="NodeSpec"):
            simulate_batch(listing2_graph(), homogeneous_cluster(2), [6.0])

    def test_unknown_vector_policy_raises(self):
        with pytest.raises(KeyError, match="no vector policy"):
            simulate_batch(listing2_graph(), homogeneous_cluster(3), [6.0],
                           policy="countdown")
