"""Per-architecture smoke tests: reduced same-family configs, one forward
+ one train-style grad step + one decode step on CPU; asserts output
shapes and absence of NaNs.  (Deliverable f.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ENCODER_ARCHS, get_smoke, runnable_cells
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn)

B, S = 2, 32


def make_batch(cfg, key):
    if cfg.family == "encoder":
        frames = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        labels = jax.random.randint(key, (B, S), 0, cfg.vocab)
        return {"frames": frames, "labels": labels}
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    labels = jnp.roll(tokens, -1, axis=1)
    return {"tokens": tokens, "labels": labels}


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_smoke(arch)
    params = init_params(cfg, rng)
    batch = make_batch(cfg, rng)
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_grad_step_finite(arch, rng):
    cfg = get_smoke(arch)
    params = init_params(cfg, rng)
    batch = make_batch(cfg, rng)

    def loss(p):
        return loss_fn(cfg, p, batch)[0]

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(val))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # gradient actually flows to the embedding/input layer
    g0 = grads.get("embed", grads.get("frame_proj"))
    assert float(jnp.abs(g0).max()) > 0


@pytest.mark.parametrize("arch",
                         [a for a in ARCH_IDS if a not in ENCODER_ARCHS])
def test_decode_step_matches_cache_semantics(arch, rng):
    cfg = get_smoke(arch)
    params = init_params(cfg, rng)
    cache = init_cache(cfg, B, S)
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab)
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    logits, cache2 = step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache pytree structure preserved, some state actually changed
    t1 = jax.tree_util.tree_leaves(cache)
    t2 = jax.tree_util.tree_leaves(cache2)
    assert len(t1) == len(t2)
    changed = any(not np.array_equal(np.asarray(a), np.asarray(b))
                  for a, b in zip(t1, t2))
    assert changed


@pytest.mark.parametrize("arch",
                         [a for a in ARCH_IDS if a not in ENCODER_ARCHS])
def test_decode_consistent_with_forward(arch, rng):
    """Greedy decode logits must match the full-sequence forward logits
    position by position (cache correctness).

    MoE note: capacity-based dispatch drops different tokens in the
    forward (16-token pool) vs decode (2-token pool) paths, so the check
    is only meaningful with drop-free capacity.
    """
    from dataclasses import replace

    cfg = get_smoke(arch)
    if cfg.family == "moe":
        cfg = replace(cfg, moe=replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, rng)
    tokens = jax.random.randint(rng, (B, 8), 0, cfg.vocab)
    full_logits, _ = forward(cfg, params, {"tokens": tokens})

    cache = init_cache(cfg, B, 8)
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    for i in range(8):
        logits_i, cache = step(params, cache, tokens[:, i: i + 1],
                               jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(logits_i[:, 0], np.float32),
            np.asarray(full_logits[:, i], np.float32),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode diverges from forward at pos {i}")


def test_cell_matrix_counts():
    """40 cells total; 31 runnable; 9 documented skips (DESIGN.md §4)."""
    from repro.configs import cells

    all_cells = cells()
    assert len(all_cells) == 40
    runnable = [c for c in all_cells if c[2] == "run"]
    assert len(runnable) == 31
    skips = [c for c in all_cells if c[2] != "run"]
    assert len(skips) == 9


def test_param_counts_in_expected_range():
    """Full configs land near their advertised sizes."""
    from repro.configs import get_config

    expect = {
        "arctic-480b": (400e9, 560e9),
        "llama3-8b": (7e9, 9.5e9),
        "granite-20b": (18e9, 24e9),
        "internlm2-20b": (17e9, 24e9),
        "qwen1.5-4b": (3e9, 5e9),
        "chameleon-34b": (30e9, 38e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
        "xlstm-350m": (0.25e9, 0.5e9),
        # the assigned 48L x 64e config; the HF checkpoint's headline 16B
        # corresponds to fewer MoE layers — we implement the assignment
        "moonshot-v1-16b-a3b": (24e9, 30e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of range"
