"""Physics invariants of the simulators, asserted against BOTH backends.

Every property here must hold for the discrete-event simulator and the
vectorized batch backend alike:

* energy is the integral of the (piecewise-constant) power trace;
* instantaneous cluster power never exceeds the bound for equal-share
  (each node is statically capped at P/n), and the ILP's *own* guarantee
  — per-depth-level cap sums within the bound — holds for its
  assignments (the paper's abstraction admits transient runtime
  violations across depth levels, audited via over_budget_time);
* makespan is bounded below by the critical path at full speed;
* makespan is monotonically non-increasing in the cluster bound;
* zero-makespan degenerate results divide safely (``speedup_vs`` /
  ``avg_power_w``).

A hypothesis fuzz layer re-checks the core invariants on randomized
Listing-2 execution times when hypothesis is installed (the ``_hyp_stub``
fallback skips it otherwise, same as the rest of the suite).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property-based tests skip without hypothesis
    from _hyp_stub import given, settings, st

from repro.core import (JobDependencyGraph, LISTING2_TIMES, ep_like,
                        heterogeneous_cluster, homogeneous_cluster,
                        listing2_graph, min_feasible_cluster_bound,
                        simulate, simulate_batch, solve_paper_ilp)
from repro.core.ilp import assignment_peak_power

BACKENDS = ("event", "vector")
DT = 0.05


def run_one(graph, specs, bound, policy, backend, trace=False):
    trace_every = 0.0 if trace else None
    if backend == "event":
        return simulate(graph, specs, bound, policy,
                        trace_every=trace_every)
    return simulate_batch(graph, specs, [bound], policy, dt=DT,
                          trace_every=trace_every)[0]


def trace_energy(trace, makespan):
    """Integral of a piecewise-constant (t, power) trace up to makespan."""
    total = 0.0
    for (t0, p0), (t1, _) in zip(trace, trace[1:]):
        total += p0 * (t1 - t0)
    if trace:
        total += trace[-1][1] * (makespan - trace[-1][0])
    return total


def critical_path_lower_bound(graph, specs):
    """Makespan can never beat every job running flat-out: at any cap a
    node's rate is at most ``speed`` work-units/s."""
    node_ids = graph.nodes
    speed = {nid: specs[k].speed for k, nid in enumerate(node_ids)}
    return graph.makespan(lambda j: j.work / speed[j.node])


@pytest.mark.parametrize("backend", BACKENDS)
class TestEnergyTraceIntegral:
    @pytest.mark.parametrize("policy", ["equal-share", "oracle",
                                        "heuristic"])
    def test_energy_equals_trace_integral(self, backend, policy):
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        r = run_one(g, specs, 6.0, policy, backend, trace=True)
        assert len(r.power_trace) > 1
        assert r.energy_j == pytest.approx(
            trace_energy(r.power_trace, r.makespan), rel=1e-6)
        assert r.avg_power_w == pytest.approx(r.energy_j / r.makespan,
                                              rel=1e-9)


@pytest.mark.parametrize("backend", BACKENDS)
class TestBoundCompliance:
    @pytest.mark.parametrize("bound", [2.5, 6.0, 12.0, 20.0])
    def test_equal_share_peak_within_bound(self, backend, bound):
        """P/n static caps with a monotone LUT can never sum above P
        (bounds at/above the duty floor — below it the translator's
        progress floor intentionally overdraws)."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        r = run_one(g, specs, bound, "equal-share", backend)
        assert r.peak_power_w <= bound + 1e-6
        assert r.over_budget_time == 0.0

    def test_oracle_never_draws_above_bound(self, backend):
        g = ep_like(4, "A")
        specs = heterogeneous_cluster(4)
        r = run_one(g, specs, 8.0, "oracle", backend)
        assert r.over_budget_time == 0.0

    def test_ilp_assignment_respects_depth_levels(self, backend):
        """The ILP's contract is per-depth-level: the assignment's
        abstracted peak fits the bound even when the simulated runtime
        transiently exceeds it across depth levels (paper §VI)."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        bound = 6.0
        assignment = solve_paper_ilp(g, specs, bound)
        assert assignment_peak_power(g, assignment, specs) <= bound + 1e-6
        r = run_one(g, specs, bound, "ilp", backend)
        assert r.avg_power_w <= bound + 1e-6


@pytest.mark.parametrize("backend", BACKENDS)
class TestMakespanBounds:
    @pytest.mark.parametrize("policy", ["equal-share", "ilp", "oracle",
                                        "heuristic"])
    def test_critical_path_lower_bound(self, backend, policy):
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        lb = critical_path_lower_bound(g, specs)
        r = run_one(g, specs, 50.0, policy, backend)   # relaxed bound
        assert r.makespan >= lb - 1e-9

    def test_critical_path_lower_bound_heterogeneous(self, backend):
        g = ep_like(4, "A")
        specs = heterogeneous_cluster(4)
        lb = critical_path_lower_bound(g, specs)
        for bound in (6.0, 30.0):
            r = run_one(g, specs, bound, "oracle", backend)
            assert r.makespan >= lb - 1e-9

    @pytest.mark.parametrize("policy", ["equal-share", "ilp", "oracle"])
    def test_makespan_monotone_in_bound(self, backend, policy):
        """More power can never slow these policies down."""
        g = listing2_graph()
        specs = homogeneous_cluster(3)
        lo = min_feasible_cluster_bound(specs)
        bounds = [lo, 1.5 * lo, 2.5 * lo, 4.0 * lo, 6.0 * lo]
        spans = [run_one(g, specs, b, policy, backend).makespan
                 for b in bounds]
        for slower, faster in zip(spans, spans[1:]):
            assert faster <= slower + 1e-9


class TestDegenerateResults:
    def zero_work_result(self, backend):
        g = JobDependencyGraph()
        g.add(0, 0, 0.0)
        g.add(1, 0, 0.0, deps=[(0, 0)])
        specs = homogeneous_cluster(2)
        return run_one(g, specs, 4.0, "equal-share", backend)

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_zero_makespan_divides_safely(self, backend):
        r0 = self.zero_work_result(backend)
        assert r0.makespan == 0.0
        assert r0.avg_power_w == 0.0
        ref = simulate(listing2_graph(), homogeneous_cluster(3), 6.0,
                       "equal-share")
        assert r0.speedup_vs(ref) == float("inf")
        assert ref.speedup_vs(r0) == 0.0
        assert r0.speedup_vs(r0) == 1.0


# ------------------------------------------------------------- fuzz layer
@st.composite
def listing2_times(draw):
    return {jid: draw(st.floats(min_value=0.0, max_value=50.0,
                                allow_nan=False, allow_infinity=False))
            for jid in LISTING2_TIMES}


@given(times=listing2_times(),
       bound=st.floats(min_value=3.0, max_value=25.0))
@settings(max_examples=25, deadline=None)
def test_fuzzed_invariants_hold_on_both_backends(times, bound):
    g = listing2_graph(times)
    specs = homogeneous_cluster(3)
    lb = critical_path_lower_bound(g, specs)
    for backend in BACKENDS:
        r = run_one(g, specs, bound, "equal-share", backend, trace=True)
        assert r.makespan >= lb - 1e-9
        assert r.peak_power_w <= bound + 1e-6
        assert r.energy_j == pytest.approx(
            trace_energy(r.power_trace, r.makespan), rel=1e-6, abs=1e-9)
    ev = run_one(g, specs, bound, "equal-share", "event")
    vec = run_one(g, specs, bound, "equal-share", "vector")
    assert vec.makespan == pytest.approx(ev.makespan, abs=2 * DT)


# ---------------------------------------------------- tie-breaking (ISSUE 9)
class TestTieBreakingDeterminism:
    """Two jobs completing at the *same instant* must resolve identically
    everywhere: the event heap pops the tied completions one by one, the
    wave backends collapse them into a single wave, and the jax engine
    resolves them inside one fori step — yet the downstream start times,
    makespan, and energy have to agree, and repeating the run must be
    bit-stable (no dict-ordering or accumulation nondeterminism)."""

    def tied_graph(self):
        g = JobDependencyGraph()
        g.add(0, 0, 6.0)
        g.add(1, 0, 6.0)          # exact tie with (0, 0) under equal caps
        g.add(2, 0, 6.0)          # triple tie
        g.add(0, 1, 3.0, deps=[(0, 0), (1, 0), (2, 0)])
        g.validate()
        return g

    @pytest.mark.parametrize("policy", ["equal-share", "oracle", "learned"])
    def test_simultaneous_completions_agree_across_backends(self, policy):
        from repro.backends.jax import HAS_JAX

        g = self.tied_graph()
        specs = homogeneous_cluster(3)
        for bound in (4.5, 9.0):
            ev = simulate(g, specs, bound, policy)
            vec = simulate_batch(g, specs, [bound], policy, dt=DT)[0]
            assert vec.makespan == pytest.approx(ev.makespan, rel=1e-9)
            assert vec.energy_j == pytest.approx(ev.energy_j, rel=1e-6)
            assert vec.job_ends.keys() == ev.job_ends.keys()
            if HAS_JAX:
                from repro.backends.jax import simulate_batch_jax

                jx = simulate_batch_jax(g, specs, [bound], policy,
                                        dt=DT)[0]
                assert jx.makespan == pytest.approx(ev.makespan, rel=1e-4)

    def test_tie_resolution_is_bit_deterministic_across_repeats(self):
        g = self.tied_graph()
        specs = homogeneous_cluster(3)
        runs_ev = [simulate(g, specs, 6.0, "learned").makespan
                   for _ in range(3)]
        runs_vec = [simulate_batch(g, specs, [6.0], "learned")[0].makespan
                    for _ in range(3)]
        assert len(set(runs_ev)) == 1
        assert len(set(runs_vec)) == 1
        assert runs_vec[0] == pytest.approx(runs_ev[0], rel=1e-12)
